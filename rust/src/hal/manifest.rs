//! Backend capability manifests and the HAL error taxonomy.
//!
//! A [`BackendManifest`] is a backend's declarative self-description:
//! which quantizer families and bit-widths it can serve, the largest
//! `[batch, seq, vocab]` shape it accepts, whether its fused
//! multi-adapter forward is a true single launch, what its
//! adapter-side cache holds, and roughly how much memory it wants.
//! The [`super::BackendRegistry`] validates a manifest once at
//! registration and a (manifest, plan, pool config) combination once
//! at construction — typed [`HalError`]s at the edge instead of
//! runtime surprises mid-drain (IR-QLoRA's versatility claim is that
//! ICQ/IEC compose with multiple quantization frameworks; the
//! manifest is where a backend states which of them it actually
//! executes).

use std::fmt;

/// A quantizer family a backend can serve (paper §4.3: IR-QLoRA
/// composes with NormalFloat- and Integer-family frameworks; QA-LoRA
/// is the group-wise integer reference point).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantFamily {
    /// NF-k codebooks (QLoRA NF4 lineage, ICQ-calibrated or not).
    NormalFloat,
    /// Group-wise integer grids (QA-LoRA lineage, GPTQ).
    Integer,
}

impl fmt::Display for QuantFamily {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QuantFamily::NormalFloat => write!(f, "nf"),
            QuantFamily::Integer => write!(f, "int"),
        }
    }
}

/// What a backend's adapter-side cache holds, i.e. what a `hit` in
/// its `UploadStats` means.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheSemantics {
    /// No adapter-side cache; every forward rebuilds adapter state.
    None,
    /// Host-side per-adapter fingerprint/summary (reference, native).
    HostFingerprint,
    /// Device-resident uploaded buffers (PJRT).
    DeviceBuffer,
}

impl fmt::Display for CacheSemantics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CacheSemantics::None => write!(f, "none"),
            CacheSemantics::HostFingerprint => write!(f, "host-fingerprint"),
            CacheSemantics::DeviceBuffer => write!(f, "device-buffer"),
        }
    }
}

/// Declarative backend capabilities. Validated by
/// [`BackendManifest::validate`] at registration.
#[derive(Clone, Debug)]
pub struct BackendManifest {
    /// Registry key (`reference`, `native`, `pjrt`, …).
    pub name: String,
    /// Quantizer families whose (dequantized/merged) models this
    /// backend serves.
    pub quant_families: Vec<QuantFamily>,
    /// Supported storage bit-widths k (each in 1..=8).
    pub bit_widths: Vec<u8>,
    /// Largest batch (rows per forward) the backend accepts.
    pub max_batch: usize,
    /// Largest padded sequence length.
    pub max_seq: usize,
    /// Largest vocab.
    pub max_vocab: usize,
    /// `true` iff `forward_fused` is a TRUE single-launch mixed-adapter
    /// forward. Backends whose fused path is the inherited per-group
    /// scatter (one launch per adapter group — correct, but not
    /// fused execution) declare `false`.
    pub fused_multi_adapter: bool,
    /// `true` iff `forward_step` is a TRUE single-position decode step
    /// (the continuous-batching hot path pays one position per step).
    /// Backends that inherit the default full-forward-then-slice step
    /// declare `false` — streaming still *works* there (the default is
    /// bit-identical), it just recomputes the whole `[batch, seq]`
    /// forward each step.
    pub streaming_decode: bool,
    /// `true` iff the backend consumes quantized base tensors straight
    /// from packed NF-k storage via the packed-domain GEMM kernels
    /// (`kernels::gemm_packed`) — no dequantized weight matrix is ever
    /// materialized on its hot path. Backends that serve from the
    /// model's dequantized f32 buffer declare `false` (correct, but
    /// they pay the full dequant round trip per tensor).
    pub packed_gemm: bool,
    /// What the adapter-side cache holds.
    pub cache: CacheSemantics,
    /// Approximate per-worker memory appetite in bytes (caches +
    /// scratch, excluding the shared base) — capacity-planning hint,
    /// not an enforced limit.
    pub approx_memory_bytes: usize,
}

impl BackendManifest {
    /// Structural validation: every field a registry can check without
    /// instantiating the backend. Returns the first problem found.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.trim().is_empty() {
            return Err("backend name is empty".into());
        }
        if self.quant_families.is_empty() {
            return Err("manifest declares no quantizer families".into());
        }
        if self.bit_widths.is_empty() {
            return Err("manifest declares no supported bit-widths".into());
        }
        for &k in &self.bit_widths {
            if !(1..=8).contains(&k) {
                return Err(format!("bit-width k={k} outside 1..=8"));
            }
        }
        if self.max_batch == 0 {
            return Err("max_batch is zero".into());
        }
        if self.max_seq == 0 {
            return Err("max_seq is zero".into());
        }
        if self.max_vocab == 0 {
            return Err("max_vocab is zero".into());
        }
        Ok(())
    }

    /// Does this manifest cover `req`? Returns the first capability
    /// gap as a human-readable reason (the registry wraps it in
    /// [`HalError::Unsupported`]).
    pub fn supports(&self, req: &super::BackendRequest) -> Result<(), String> {
        if req.batch == 0 || req.seq == 0 || req.vocab == 0 {
            return Err(format!(
                "requested shape [{}, {}, {}] has a zero dimension",
                req.batch, req.seq, req.vocab
            ));
        }
        if req.batch > self.max_batch {
            return Err(format!(
                "requested batch {} exceeds max_batch {}",
                req.batch, self.max_batch
            ));
        }
        if req.seq > self.max_seq {
            return Err(format!(
                "requested seq {} exceeds max_seq {}",
                req.seq, self.max_seq
            ));
        }
        if req.vocab > self.max_vocab {
            return Err(format!(
                "requested vocab {} exceeds max_vocab {}",
                req.vocab, self.max_vocab
            ));
        }
        for &k in &req.bit_widths {
            if !self.bit_widths.contains(&k) {
                return Err(format!(
                    "plan uses k={k}, backend supports {:?}",
                    self.bit_widths
                ));
            }
        }
        if let Some(fam) = req.family {
            if !self.quant_families.contains(&fam) {
                return Err(format!("quantizer family '{fam}' not supported"));
            }
        }
        if req.require_fused && !self.fused_multi_adapter {
            return Err(
                "single-launch fused multi-adapter forward required but not offered".into(),
            );
        }
        if req.require_streaming && !self.streaming_decode {
            return Err(
                "single-position streaming decode required but not offered".into(),
            );
        }
        if req.require_packed_gemm && !self.packed_gemm {
            return Err("packed-domain GEMM required but not offered".into());
        }
        Ok(())
    }
}

/// Construction-time HAL failures: everything that can go wrong
/// BEFORE a backend serves its first request. Runtime serving
/// failures stay in `coordinator::ServeError`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HalError {
    /// No backend registered under this name.
    UnknownBackend {
        name: String,
        /// What IS registered, for the error message.
        available: Vec<String>,
    },
    /// Registered, but its gate (feature/env/artifact check) refused.
    Unavailable { name: String, reason: String },
    /// The manifest failed structural validation at registration (or
    /// contradicts the implementation, e.g. fused claimed but not
    /// implemented).
    InvalidManifest { name: String, reason: String },
    /// A name was registered twice.
    DuplicateBackend { name: String },
    /// The manifest cannot cover the requested (plan, pool config)
    /// combination.
    Unsupported { backend: String, reason: String },
}

impl fmt::Display for HalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HalError::UnknownBackend { name, available } => write!(
                f,
                "unknown backend '{name}' (registered: {})",
                available.join(", ")
            ),
            HalError::Unavailable { name, reason } => {
                write!(f, "backend '{name}' unavailable: {reason}")
            }
            HalError::InvalidManifest { name, reason } => {
                write!(f, "invalid manifest for backend '{name}': {reason}")
            }
            HalError::DuplicateBackend { name } => {
                write!(f, "backend '{name}' is already registered")
            }
            HalError::Unsupported { backend, reason } => {
                write!(f, "backend '{backend}' cannot serve this plan: {reason}")
            }
        }
    }
}

impl std::error::Error for HalError {}

#[cfg(test)]
mod tests {
    use super::super::BackendRequest;
    use super::*;

    fn good() -> BackendManifest {
        BackendManifest {
            name: "t".into(),
            quant_families: vec![QuantFamily::NormalFloat],
            bit_widths: vec![2, 4],
            max_batch: 8,
            max_seq: 32,
            max_vocab: 64,
            fused_multi_adapter: true,
            streaming_decode: true,
            packed_gemm: true,
            cache: CacheSemantics::HostFingerprint,
            approx_memory_bytes: 1 << 20,
        }
    }

    #[test]
    fn validate_accepts_well_formed() {
        assert_eq!(good().validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_malformed() {
        let mut m = good();
        m.bit_widths = vec![0];
        assert!(m.validate().unwrap_err().contains("outside 1..=8"));
        let mut m = good();
        m.bit_widths = vec![4, 9];
        assert!(m.validate().unwrap_err().contains("k=9"));
        let mut m = good();
        m.max_batch = 0;
        assert!(m.validate().unwrap_err().contains("max_batch"));
        let mut m = good();
        m.bit_widths.clear();
        assert!(m.validate().is_err());
        let mut m = good();
        m.name = "  ".into();
        assert!(m.validate().is_err());
        let mut m = good();
        m.quant_families.clear();
        assert!(m.validate().is_err());
    }

    #[test]
    fn supports_checks_shape_k_family_fused() {
        let m = good();
        let ok = BackendRequest::new(8, 32, 64);
        assert_eq!(m.supports(&ok), Ok(()));

        let mut req = BackendRequest::new(9, 32, 64);
        assert!(m.supports(&req).unwrap_err().contains("batch"));
        req = BackendRequest::new(8, 33, 64);
        assert!(m.supports(&req).unwrap_err().contains("seq"));
        req = BackendRequest::new(8, 32, 65);
        assert!(m.supports(&req).unwrap_err().contains("vocab"));

        req = BackendRequest::new(8, 32, 64);
        req.bit_widths = vec![4, 3];
        assert!(m.supports(&req).unwrap_err().contains("k=3"));

        req = BackendRequest::new(8, 32, 64);
        req.family = Some(QuantFamily::Integer);
        assert!(m.supports(&req).unwrap_err().contains("family"));

        let mut unfused = good();
        unfused.fused_multi_adapter = false;
        req = BackendRequest::new(8, 32, 64);
        req.require_fused = true;
        assert!(unfused.supports(&req).is_err());
        assert_eq!(m.supports(&req), Ok(()));

        let mut sliced = good();
        sliced.streaming_decode = false;
        req = BackendRequest::new(8, 32, 64);
        req.require_streaming = true;
        assert!(sliced
            .supports(&req)
            .unwrap_err()
            .contains("streaming decode"));
        assert_eq!(m.supports(&req), Ok(()));

        let mut dequant = good();
        dequant.packed_gemm = false;
        req = BackendRequest::new(8, 32, 64);
        req.require_packed_gemm = true;
        assert!(dequant
            .supports(&req)
            .unwrap_err()
            .contains("packed-domain GEMM"));
        assert_eq!(m.supports(&req), Ok(()));
    }

    #[test]
    fn hal_error_display_is_matchable() {
        let e = HalError::UnknownBackend {
            name: "x".into(),
            available: vec!["reference".into(), "native".into()],
        };
        let s = e.to_string();
        assert!(s.contains("unknown backend 'x'") && s.contains("reference"));
        let e = HalError::Unsupported { backend: "pjrt".into(), reason: "nope".into() };
        assert!(e.to_string().contains("cannot serve this plan"));
        // converts into the vendored anyhow shim via `?`
        fn f() -> anyhow::Result<()> {
            Err(HalError::DuplicateBackend { name: "d".into() })?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("already registered"));
    }
}
