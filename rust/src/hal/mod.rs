//! Hardware abstraction layer for serving backends.
//!
//! Everything execution-touching goes through here: a backend
//! declares what it can do in a [`BackendManifest`], registers a
//! factory in the [`BackendRegistry`] under a name (feature-/
//! artifact-gated where applicable), and callers resolve a
//! `(manifest, plan, pool config)` combination — as a
//! [`BackendRequest`] — ONCE, at construction time, getting a typed
//! [`HalError`] instead of a runtime surprise mid-drain. The CLI
//! (`irqlora serve --backend NAME`, `irqlora backends`), the serving
//! pool, the latency bench, and the cross-backend test batteries all
//! select backends through this registry.
//!
//! In-tree backends:
//!
//! - `reference` — the deterministic host-side oracle
//!   ([`crate::coordinator::ReferenceBackend`]); always available,
//!   and the bit-identity yardstick for everything else;
//! - `native` — the cache-blocked, row-parallel CPU backend
//!   ([`NativeBackend`]), bit-identical to `reference` with a true
//!   single-launch fused path and streaming quantized construction;
//! - `pjrt` — the compiled-graph backend
//!   ([`crate::coordinator::PjrtBackend`]); registered behind an
//!   artifact gate (and today the vendored `xla` stub), so the
//!   real-PJRT restore is a factory swap, not a refactor.

pub mod manifest;
pub mod native;
pub mod registry;

pub use manifest::{BackendManifest, CacheSemantics, HalError, QuantFamily};
pub use native::NativeBackend;
pub use registry::{BackendCtx, BackendEntry, BackendRegistry, BackendRequest};
