//! Named backend registration and construction-time validation.
//!
//! The [`BackendRegistry`] is the HAL's front door: factories are
//! registered under a name together with their [`BackendManifest`],
//! validated at registration (malformed or contradictory manifests
//! are refused with a typed [`HalError`], not discovered at drain
//! time), and resolved against a [`BackendRequest`] — the serving
//! plan's shape, bit-widths, and quantizer family — before a single
//! worker spawns. `builtin()` registers the three in-tree backends
//! (`reference`, `native`, `pjrt`), the `native`/`pjrt` entries
//! behind cargo features so a trimmed build simply doesn't list them.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{QuantizedModel, ServeBackend};
use crate::model::weights::NamedTensors;
use crate::quant::Method;

use super::manifest::{BackendManifest, CacheSemantics, HalError, QuantFamily};

/// What the caller wants to serve: pool shape plus the plan's
/// quantization footprint. Checked against a manifest by
/// [`BackendManifest::supports`] at construction time.
#[derive(Clone, Debug)]
pub struct BackendRequest {
    /// Rows per forward (the pool's padded batch).
    pub batch: usize,
    /// Padded sequence length.
    pub seq: usize,
    /// Vocab size.
    pub vocab: usize,
    /// Distinct storage bit-widths the base model's plan uses (empty
    /// = unconstrained, e.g. a synthetic f32 fixture).
    pub bit_widths: Vec<u8>,
    /// Quantizer family of the base model, if quantized.
    pub family: Option<QuantFamily>,
    /// Demand a TRUE single-launch fused multi-adapter forward (the
    /// inherited per-group scatter is correct but does not qualify).
    pub require_fused: bool,
    /// Demand a TRUE single-position streaming decode step (the
    /// inherited full-forward-then-slice default is correct but does
    /// not qualify).
    pub require_streaming: bool,
    /// Demand packed-domain GEMM consumption of quantized storage
    /// (`kernels::gemm_packed` — no dequantized weight matrix on the
    /// hot path). Backends serving from the dequantized f32 buffer are
    /// correct but do not qualify.
    pub require_packed_gemm: bool,
    /// Worker count the pool will spawn (capacity-planning hint).
    pub workers: usize,
}

impl BackendRequest {
    /// An unconstrained request for a `[batch, seq, vocab]` pool.
    pub fn new(batch: usize, seq: usize, vocab: usize) -> BackendRequest {
        BackendRequest {
            batch,
            seq,
            vocab,
            bit_widths: Vec::new(),
            family: None,
            require_fused: false,
            require_streaming: false,
            require_packed_gemm: false,
            workers: 1,
        }
    }

    /// Derive the quantization footprint from a quantized model: the
    /// distinct per-tensor bit-widths actually stored and the method's
    /// quantizer family.
    pub fn from_plan(
        batch: usize,
        seq: usize,
        vocab: usize,
        qm: &QuantizedModel,
    ) -> BackendRequest {
        let mut req = BackendRequest::new(batch, seq, vocab);
        req.family = match qm.method {
            Method::Fp16 => None,
            Method::Nf { .. } | Method::NfIcq { .. } | Method::Planned => {
                Some(QuantFamily::NormalFloat)
            }
            Method::Int { .. } | Method::IntIcq { .. } | Method::Gptq { .. } => {
                Some(QuantFamily::Integer)
            }
        };
        let mut ks: Vec<u8> = qm.storage.iter().map(|(_, qt)| qt.k).collect();
        ks.sort_unstable();
        ks.dedup();
        req.bit_widths = ks;
        req
    }
}

/// Everything a factory gets to build ONE worker's backend.
pub struct BackendCtx {
    /// The validated request the pool was constructed with.
    pub request: BackendRequest,
    /// The registry's shared (dequantized) base weights.
    pub base: Arc<NamedTensors>,
    /// Model size tag (PJRT graph selection).
    pub tag: String,
    /// Worker index within the pool.
    pub worker: usize,
}

/// Per-worker backend factory.
pub type BackendFactory =
    Arc<dyn Fn(&BackendCtx) -> Result<Box<dyn ServeBackend>> + Send + Sync>;

/// Availability gate: an entry may be registered but temporarily
/// unusable (missing artifacts, stubbed dependency, env opt-out).
pub type BackendGate = Arc<dyn Fn() -> Result<(), String> + Send + Sync>;

/// One registered backend: manifest + factory (+ optional gate).
pub struct BackendEntry {
    pub manifest: BackendManifest,
    /// Does the implementation actually override `forward_fused` with
    /// a single-launch mixed batch? Cross-checked against
    /// `manifest.fused_multi_adapter` at registration — claiming fused
    /// without implementing it is a manifest contradiction.
    pub implements_fused: bool,
    /// Does the implementation actually override `forward_step` with a
    /// single-position decode? Cross-checked against
    /// `manifest.streaming_decode` at registration, same as the fused
    /// claim.
    pub implements_step: bool,
    /// Does the implementation actually do its quantized-storage math
    /// through the packed-domain kernels (`kernels::gemm_packed` /
    /// `dot_packed`)? Cross-checked against `manifest.packed_gemm` at
    /// registration — claiming packed GEMM while serving from the
    /// dequantized buffer is a manifest contradiction.
    pub implements_packed_gemm: bool,
    /// `None` = always available.
    pub gate: Option<BackendGate>,
    pub factory: BackendFactory,
}

/// Named, validated backend entries. `BTreeMap` so listings and the
/// capability table are deterministically ordered.
#[derive(Default)]
pub struct BackendRegistry {
    entries: BTreeMap<String, BackendEntry>,
}

impl BackendRegistry {
    /// An empty registry (tests, embedders with custom backends).
    pub fn new() -> BackendRegistry {
        BackendRegistry { entries: BTreeMap::new() }
    }

    /// The in-tree backends. `reference` is unconditional (it is the
    /// bit-identity oracle everything else is judged against);
    /// `native` and `pjrt` ride behind cargo features.
    pub fn builtin() -> BackendRegistry {
        let mut r = BackendRegistry::new();
        r.register(reference_entry()).expect("builtin reference entry must validate");
        #[cfg(feature = "backend-native")]
        r.register(native_entry()).expect("builtin native entry must validate");
        #[cfg(feature = "backend-pjrt")]
        r.register(pjrt_entry()).expect("builtin pjrt entry must validate");
        r
    }

    /// Validate and insert. Typed rejection for malformed manifests,
    /// manifest/implementation contradictions, and duplicate names.
    pub fn register(&mut self, entry: BackendEntry) -> Result<(), HalError> {
        let name = entry.manifest.name.clone();
        entry
            .manifest
            .validate()
            .map_err(|reason| HalError::InvalidManifest { name: name.clone(), reason })?;
        if entry.manifest.fused_multi_adapter && !entry.implements_fused {
            return Err(HalError::InvalidManifest {
                name,
                reason: "manifest claims a single-launch fused multi-adapter forward \
                         but the implementation does not provide one"
                    .into(),
            });
        }
        if entry.manifest.streaming_decode && !entry.implements_step {
            return Err(HalError::InvalidManifest {
                name,
                reason: "manifest claims a single-position streaming decode step \
                         but the implementation does not provide one"
                    .into(),
            });
        }
        if entry.manifest.packed_gemm && !entry.implements_packed_gemm {
            return Err(HalError::InvalidManifest {
                name,
                reason: "manifest claims packed-domain GEMM consumption of quantized \
                         storage but the implementation does not provide it"
                    .into(),
            });
        }
        if self.entries.contains_key(&name) {
            return Err(HalError::DuplicateBackend { name });
        }
        self.entries.insert(name, entry);
        Ok(())
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn get(&self, name: &str) -> Option<&BackendEntry> {
        self.entries.get(name)
    }

    /// Full construction-time check: the name exists, its gate admits,
    /// and its manifest covers `req`. This is the call that turns
    /// "runtime surprise mid-drain" into a typed error before any
    /// worker spawns.
    pub fn resolve(&self, name: &str, req: &BackendRequest) -> Result<&BackendEntry, HalError> {
        let entry = self.entries.get(name).ok_or_else(|| HalError::UnknownBackend {
            name: name.to_string(),
            available: self.names(),
        })?;
        if let Some(gate) = &entry.gate {
            gate().map_err(|reason| HalError::Unavailable {
                name: name.to_string(),
                reason,
            })?;
        }
        entry.manifest.supports(req).map_err(|reason| HalError::Unsupported {
            backend: name.to_string(),
            reason,
        })?;
        Ok(entry)
    }

    /// Resolve `name` for `req` and return a per-worker factory in the
    /// shape `ServerPool::spawn_with` takes. Validation happens HERE,
    /// once; the returned closure only instantiates.
    pub fn pool_factory(
        &self,
        name: &str,
        req: &BackendRequest,
        base: Arc<NamedTensors>,
        tag: &str,
    ) -> Result<
        impl Fn(usize) -> Result<Box<dyn ServeBackend>> + Send + Sync + 'static,
        HalError,
    > {
        let entry = self.resolve(name, req)?;
        let factory = entry.factory.clone();
        let req = req.clone();
        let tag = tag.to_string();
        Ok(move |worker: usize| {
            let ctx = BackendCtx {
                request: req.clone(),
                base: base.clone(),
                tag: tag.clone(),
                worker,
            };
            factory(&ctx)
        })
    }

    /// Whether `name` would pass its gate right now (the capability
    /// table's "available" column).
    pub fn availability(&self, name: &str) -> Result<(), String> {
        match self.entries.get(name) {
            None => Err("not registered".into()),
            Some(e) => match &e.gate {
                None => Ok(()),
                Some(g) => g(),
            },
        }
    }

    /// Markdown capability table — what `irqlora backends` prints and
    /// what the README's backend table is generated from.
    pub fn capability_table(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "| Backend | Families | Bit-widths k | Max batch×seq×vocab | \
             Fused multi-adapter | Streaming | Packed GEMM | Cache | ~Mem/worker | Available |\n",
        );
        s.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
        for (name, e) in &self.entries {
            let m = &e.manifest;
            let families = m
                .quant_families
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("+");
            let ks = m
                .bit_widths
                .iter()
                .map(|k| k.to_string())
                .collect::<Vec<_>>()
                .join(",");
            let avail = match self.availability(name) {
                Ok(()) => "yes".to_string(),
                Err(reason) => format!("no — {reason}"),
            };
            s.push_str(&format!(
                "| `{name}` | {families} | {ks} | {}×{}×{} | {} | {} | {} | {} | {} | {avail} |\n",
                m.max_batch,
                m.max_seq,
                m.max_vocab,
                if m.fused_multi_adapter { "yes" } else { "scatter" },
                if m.streaming_decode { "yes" } else { "sliced" },
                if m.packed_gemm { "yes" } else { "dequant" },
                m.cache,
                fmt_mem(m.approx_memory_bytes),
            ));
        }
        s
    }
}

fn fmt_mem(bytes: usize) -> String {
    if bytes >= 1 << 30 {
        format!("{} GiB", bytes >> 30)
    } else if bytes >= 1 << 20 {
        format!("{} MiB", bytes >> 20)
    } else if bytes >= 1 << 10 {
        format!("{} KiB", bytes >> 10)
    } else {
        format!("{bytes} B")
    }
}

const ALL_K: [u8; 8] = [1, 2, 3, 4, 5, 6, 7, 8];

/// `reference`: the deterministic host-side oracle. Serves anything —
/// it consumes already-dequantized merged weights, so every family
/// and bit-width reduces to the same f32 path.
fn reference_entry() -> BackendEntry {
    BackendEntry {
        manifest: BackendManifest {
            name: "reference".into(),
            quant_families: vec![QuantFamily::NormalFloat, QuantFamily::Integer],
            bit_widths: ALL_K.to_vec(),
            max_batch: 1024,
            max_seq: 8192,
            max_vocab: 1 << 20,
            fused_multi_adapter: true,
            streaming_decode: true,
            packed_gemm: false,
            cache: CacheSemantics::HostFingerprint,
            approx_memory_bytes: 1 << 20,
        },
        implements_fused: true,
        implements_step: true,
        implements_packed_gemm: false,
        gate: None,
        factory: Arc::new(|ctx: &BackendCtx| {
            let r = &ctx.request;
            Ok(Box::new(crate::coordinator::ReferenceBackend::new(
                r.batch, r.seq, r.vocab, &ctx.base,
            )) as Box<dyn ServeBackend>)
        }),
    }
}

/// `native`: the cache-blocked CPU backend (`hal::native`), fused
/// natively, bit-identical to `reference`. Declares `packed_gemm`: its
/// quantized-base construction path (`NativeBackend::from_quantized`)
/// folds packed NF-k tiles through `kernels::dot_packed` without ever
/// materializing the dequantized tensor.
#[cfg(feature = "backend-native")]
fn native_entry() -> BackendEntry {
    BackendEntry {
        manifest: BackendManifest {
            name: "native".into(),
            quant_families: vec![QuantFamily::NormalFloat, QuantFamily::Integer],
            bit_widths: ALL_K.to_vec(),
            max_batch: 1024,
            max_seq: 8192,
            max_vocab: 1 << 20,
            fused_multi_adapter: true,
            streaming_decode: true,
            packed_gemm: true,
            cache: CacheSemantics::HostFingerprint,
            approx_memory_bytes: 1 << 26,
        },
        implements_fused: true,
        implements_step: true,
        implements_packed_gemm: true,
        gate: None,
        factory: Arc::new(|ctx: &BackendCtx| {
            let r = &ctx.request;
            Ok(Box::new(super::native::NativeBackend::new(
                r.batch, r.seq, r.vocab, &ctx.base,
            )) as Box<dyn ServeBackend>)
        }),
    }
}

/// `pjrt`: the compiled-graph backend. Its fused path is the
/// inherited per-group scatter (one graph launch per adapter group;
/// the device cache is what it wins with), so `fused_multi_adapter`
/// is declared `false`. Gated on compiled artifacts being present —
/// and the vendored `xla` being real, which today it is not (the
/// real-PJRT restore is a ROADMAP carry-over; this entry is its
/// landing pad, so the swap is a Cargo.toml edit, not a refactor).
#[cfg(feature = "backend-pjrt")]
fn pjrt_entry() -> BackendEntry {
    BackendEntry {
        manifest: BackendManifest {
            name: "pjrt".into(),
            quant_families: vec![QuantFamily::NormalFloat, QuantFamily::Integer],
            bit_widths: ALL_K.to_vec(),
            max_batch: 64,
            max_seq: 2048,
            max_vocab: 1 << 17,
            fused_multi_adapter: false,
            streaming_decode: false,
            packed_gemm: false,
            cache: CacheSemantics::DeviceBuffer,
            approx_memory_bytes: 1 << 30,
        },
        implements_fused: false,
        implements_step: false,
        implements_packed_gemm: false,
        gate: Some(Arc::new(|| {
            if !std::path::Path::new("artifacts/manifest.json").exists() {
                return Err(
                    "no artifacts/manifest.json (run `make artifacts`; note the vendored \
                     `xla` is an offline stub — real PJRT restore is a ROADMAP carry-over)"
                        .into(),
                );
            }
            Ok(())
        })),
        factory: Arc::new(|ctx: &BackendCtx| {
            let manifest = crate::runtime::Manifest::load("artifacts")?;
            Ok(Box::new(crate::coordinator::PjrtBackend::new(
                &manifest, &ctx.tag, &ctx.base,
            )?) as Box<dyn ServeBackend>)
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_entry(name: &str) -> BackendEntry {
        BackendEntry {
            manifest: BackendManifest {
                name: name.into(),
                quant_families: vec![QuantFamily::NormalFloat],
                bit_widths: vec![4],
                max_batch: 4,
                max_seq: 8,
                max_vocab: 16,
                fused_multi_adapter: false,
                streaming_decode: false,
                packed_gemm: false,
                cache: CacheSemantics::None,
                approx_memory_bytes: 1024,
            },
            implements_fused: false,
            implements_step: false,
            implements_packed_gemm: false,
            gate: None,
            factory: Arc::new(|ctx: &BackendCtx| {
                let r = &ctx.request;
                Ok(Box::new(crate::coordinator::ReferenceBackend::new(
                    r.batch, r.seq, r.vocab, &ctx.base,
                )) as Box<dyn ServeBackend>)
            }),
        }
    }

    #[test]
    fn builtin_lists_reference_native_pjrt() {
        let r = BackendRegistry::builtin();
        let names = r.names();
        assert!(names.contains(&"reference".to_string()), "{names:?}");
        assert!(names.contains(&"native".to_string()), "{names:?}");
        assert!(names.contains(&"pjrt".to_string()), "{names:?}");
        // reference and native are gate-free; pjrt is gated on
        // artifacts (absent in the offline build)
        assert!(r.availability("reference").is_ok());
        assert!(r.availability("native").is_ok());
        let table = r.capability_table();
        for n in ["reference", "native", "pjrt"] {
            assert!(table.contains(&format!("`{n}`")), "{table}");
        }
    }

    #[test]
    fn registration_rejects_malformed_manifests_typed() {
        let mut r = BackendRegistry::new();

        // k outside 1..=8
        let mut e = dummy_entry("bad-k");
        e.manifest.bit_widths = vec![4, 9];
        match r.register(e) {
            Err(HalError::InvalidManifest { name, reason }) => {
                assert_eq!(name, "bad-k");
                assert!(reason.contains("k=9"), "{reason}");
            }
            other => panic!("expected InvalidManifest, got {other:?}"),
        }

        // zero max_batch
        let mut e = dummy_entry("zero-batch");
        e.manifest.max_batch = 0;
        match r.register(e) {
            Err(HalError::InvalidManifest { reason, .. }) => {
                assert!(reason.contains("max_batch"), "{reason}");
            }
            other => panic!("expected InvalidManifest, got {other:?}"),
        }

        // fused claimed but unimplemented: a contradiction, not a typo
        let mut e = dummy_entry("liar");
        e.manifest.fused_multi_adapter = true;
        e.implements_fused = false;
        match r.register(e) {
            Err(HalError::InvalidManifest { reason, .. }) => {
                assert!(reason.contains("fused"), "{reason}");
            }
            other => panic!("expected InvalidManifest, got {other:?}"),
        }

        // streaming claimed but unimplemented: same contradiction class
        let mut e = dummy_entry("stream-liar");
        e.manifest.streaming_decode = true;
        e.implements_step = false;
        match r.register(e) {
            Err(HalError::InvalidManifest { reason, .. }) => {
                assert!(reason.contains("streaming"), "{reason}");
            }
            other => panic!("expected InvalidManifest, got {other:?}"),
        }

        // packed GEMM claimed but unimplemented: same contradiction class
        let mut e = dummy_entry("packed-liar");
        e.manifest.packed_gemm = true;
        e.implements_packed_gemm = false;
        match r.register(e) {
            Err(HalError::InvalidManifest { reason, .. }) => {
                assert!(reason.contains("packed"), "{reason}");
            }
            other => panic!("expected InvalidManifest, got {other:?}"),
        }

        // duplicates are typed too
        r.register(dummy_entry("dup")).unwrap();
        match r.register(dummy_entry("dup")) {
            Err(HalError::DuplicateBackend { name }) => assert_eq!(name, "dup"),
            other => panic!("expected DuplicateBackend, got {other:?}"),
        }
    }

    #[test]
    fn resolve_is_typed_end_to_end() {
        let mut r = BackendRegistry::new();
        r.register(dummy_entry("tiny")).unwrap();

        match r.resolve("nope", &BackendRequest::new(1, 1, 1)) {
            Err(HalError::UnknownBackend { name, available }) => {
                assert_eq!(name, "nope");
                assert_eq!(available, vec!["tiny".to_string()]);
            }
            other => panic!("expected UnknownBackend, got {:?}", other.err()),
        }

        // shape beyond the manifest: Unsupported at construction time
        match r.resolve("tiny", &BackendRequest::new(5, 8, 16)) {
            Err(HalError::Unsupported { backend, reason }) => {
                assert_eq!(backend, "tiny");
                assert!(reason.contains("batch"), "{reason}");
            }
            other => panic!("expected Unsupported, got {:?}", other.err()),
        }

        // unsupported k from the plan
        let mut req = BackendRequest::new(4, 8, 16);
        req.bit_widths = vec![2];
        assert!(matches!(
            r.resolve("tiny", &req),
            Err(HalError::Unsupported { .. })
        ));

        // demanding true fused from a scatter backend
        let mut req = BackendRequest::new(4, 8, 16);
        req.require_fused = true;
        assert!(matches!(
            r.resolve("tiny", &req),
            Err(HalError::Unsupported { .. })
        ));

        // demanding true streaming decode from a sliced-step backend
        let mut req = BackendRequest::new(4, 8, 16);
        req.require_streaming = true;
        assert!(matches!(
            r.resolve("tiny", &req),
            Err(HalError::Unsupported { .. })
        ));

        // demanding packed-domain GEMM from a dequant-path backend
        let mut req = BackendRequest::new(4, 8, 16);
        req.require_packed_gemm = true;
        assert!(matches!(
            r.resolve("tiny", &req),
            Err(HalError::Unsupported { .. })
        ));

        // a gated entry reports Unavailable with the gate's reason
        let mut gated = dummy_entry("gated");
        gated.gate = Some(Arc::new(|| Err("artifacts missing".into())));
        r.register(gated).unwrap();
        match r.resolve("gated", &BackendRequest::new(4, 8, 16)) {
            Err(HalError::Unavailable { reason, .. }) => {
                assert!(reason.contains("artifacts"), "{reason}");
            }
            other => panic!("expected Unavailable, got {:?}", other.err()),
        }

        // the happy path still resolves
        assert!(r.resolve("tiny", &BackendRequest::new(4, 8, 16)).is_ok());
    }

    #[test]
    fn pool_factory_builds_working_workers() {
        use crate::model::weights::NamedTensors;
        use crate::util::{Rng, Tensor};

        let mut rng = Rng::new(5);
        let mut base = NamedTensors::new();
        base.push("w", Tensor::new(&[32], rng.normal_vec(32, 0.0, 1.0)));
        let base = Arc::new(base);

        let reg = BackendRegistry::builtin();
        let req = BackendRequest::new(2, 4, 8);
        let make = reg.pool_factory("reference", &req, base.clone(), "xs").unwrap();
        let mut be = make(0).unwrap();
        assert_eq!(be.shape(), (2, 4, 8));
        let w = Arc::new(NamedTensors::new());
        let toks = vec![1i32; 2 * 4];
        assert_eq!(be.forward("a", 0, &w, &toks).unwrap().len(), 2 * 4 * 8);

        // pjrt resolves to a typed Unavailable without artifacts
        match reg.pool_factory("pjrt", &req, base, "xs") {
            Ok(_) => {
                // only reachable in a checkout that has artifacts
                assert!(std::path::Path::new("artifacts/manifest.json").exists());
            }
            Err(HalError::Unavailable { reason, .. }) => {
                assert!(reason.contains("artifacts"), "{reason}");
            }
            Err(other) => panic!("expected Unavailable, got {other:?}"),
        }
    }
}
