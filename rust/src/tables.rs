//! Paper-format table and figure renderers (Tables 1–13, Figures 4–5).
//!
//! Each `table_*` function runs the arms the paper compares and prints
//! rows in the paper's own layout so EXPERIMENTS.md can record
//! paper-vs-measured side by side. Absolute numbers live on the
//! synthetic substrate (DESIGN.md §2/§10); the claims being reproduced
//! are the *orderings and gaps* between methods.

use anyhow::Result;

use crate::coordinator::{pretrained_base, run_arm, Arm, ArmResult, RunCfg};
use crate::data::evalset::{csqa_set, mmlu_set};
use crate::data::instruct::Dataset;
use crate::data::{World, CSQA_SUITES, MMLU_GROUPS};
use crate::quant::nf;
use crate::runtime::{Manifest, Runtime};
use crate::util::timer::fmt_duration;

/// Print the MMLU header row.
fn mmlu_header(extra: &str) {
    println!(
        "{:<22} {:>5} {:>7} {:>7} {:>7} {:>7} {:>7}{extra}",
        "Method", "#Bit", "Hums.", "STEM", "Social", "Other", "Avg."
    );
}

fn mmlu_row(r: &ArmResult, extra: &str) {
    print!("{:<22} {:>5} ", r.arm.name, r.arm.method.bits());
    for g in 0..MMLU_GROUPS.len() {
        print!("{:>7.1} ", r.eval.group_accuracy(g) * 100.0);
    }
    println!("{:>7.1}{extra}", r.eval.avg_accuracy() * 100.0);
}

/// Arms for the main comparison tables (Tables 1/2/3).
fn main_arms(k: u8) -> Vec<Arm> {
    vec![
        Arm::fp16(),
        Arm::normalfloat(k),
        Arm::qlora_gptq(k),
        Arm::qlora(k),
        Arm::qalora(k),
        Arm::ir_qlora(k),
    ]
}

/// Tables 1 (Alpaca) and 2 (Flan v2): MMLU across model sizes.
pub fn table_main(
    rt: &Runtime,
    manifest: &Manifest,
    dataset: Dataset,
    sizes: &[&str],
    cfg: &RunCfg,
) -> Result<()> {
    let n = match dataset {
        Dataset::AlpacaSyn => 1,
        Dataset::FlanSyn => 2,
    };
    println!(
        "\n=== Table {n}: SynMMLU accuracy (%), finetuned on {} ===",
        dataset.paper_name()
    );
    let world = World::new(cfg.world_seed);
    for tag in sizes {
        let base = pretrained_base(rt, manifest, tag, cfg)?;
        let items = mmlu_set(&world, cfg.eval_per_group, cfg.seed);
        println!("\n--- NanoLLaMA-{tag} (analog of LLaMA-{}) ---",
            crate::tables::paper_analog(tag));
        mmlu_header("");
        for arm in main_arms(4) {
            let r = run_arm(rt, manifest, tag, &base, arm, dataset, &items, cfg)?;
            mmlu_row(&r, "");
        }
    }
    Ok(())
}

/// Table 3: LLaMA2-analog generalization (fresh world + init seeds).
pub fn table3(rt: &Runtime, manifest: &Manifest, sizes: &[&str], cfg: &RunCfg) -> Result<()> {
    println!("\n=== Table 3: SynMMLU accuracy (%) on the NanoLLaMA2 family ===");
    let mut cfg2 = cfg.clone();
    cfg2.world_seed = cfg.world_seed.wrapping_add(0x11a2);
    cfg2.seed = cfg.seed.wrapping_add(0x11a2);
    let world = World::new(cfg2.world_seed);
    for tag in sizes {
        let base = pretrained_base(rt, manifest, tag, &cfg2)?;
        let items = mmlu_set(&world, cfg2.eval_per_group, cfg2.seed);
        println!("\n--- NanoLLaMA2-{tag} ---");
        mmlu_header("");
        for dataset in [Dataset::AlpacaSyn, Dataset::FlanSyn] {
            println!("  [finetune: {}]", dataset.paper_name());
            for arm in [Arm::normalfloat(4), Arm::qalora(4), Arm::ir_qlora(4)] {
                let r = run_arm(rt, manifest, tag, &base, arm, dataset, &items, &cfg2)?;
                mmlu_row(&r, "");
            }
        }
    }
    Ok(())
}

/// Table 4: ablation (Vanilla / ICQ / IEC(U1) / IEC(U2) / IEC / IR-QLoRA).
pub fn table4(rt: &Runtime, manifest: &Manifest, tag: &str, cfg: &RunCfg) -> Result<()> {
    println!("\n=== Table 4: ablation on SynMMLU (NanoLLaMA-{tag}, 4-bit, Alpaca) ===");
    let world = World::new(cfg.world_seed);
    let base = pretrained_base(rt, manifest, tag, cfg)?;
    let items = mmlu_set(&world, cfg.eval_per_group, cfg.seed);
    mmlu_header("");
    let arms = vec![
        Arm::fp16(),
        Arm { name: "Vanilla", ..Arm::qlora(4) },
        Arm::icq_only(4),
        Arm::iec_u1(4),
        Arm::iec_u2(4),
        Arm::iec_only(4),
        Arm::ir_qlora(4),
    ];
    for arm in arms {
        let r = run_arm(rt, manifest, tag, &base, arm, Dataset::AlpacaSyn, &items, cfg)?;
        mmlu_row(&r, "");
    }
    Ok(())
}

/// Table 5: ICQ without LoRA/finetuning — accuracy + entropy.
pub fn table5(rt: &Runtime, manifest: &Manifest, tag: &str, cfg: &RunCfg) -> Result<()> {
    println!("\n=== Table 5: ICQ without LoRA and finetuning (NanoLLaMA-{tag}) ===");
    let world = World::new(cfg.world_seed);
    let base = pretrained_base(rt, manifest, tag, cfg)?;
    let items = mmlu_set(&world, cfg.eval_per_group, cfg.seed);
    mmlu_header("    Ent.");
    for arm in [Arm::fp16(), Arm::normalfloat(4), Arm::icq_no_ft(4)] {
        let r = run_arm(rt, manifest, tag, &base, arm, Dataset::AlpacaSyn, &items, cfg)?;
        let ent = if r.arm.method.bits() < 16 {
            format!("  {:>6.2}", r.mean_entropy)
        } else {
            "       -".to_string()
        };
        mmlu_row(&r, &ent);
    }
    Ok(())
}

/// Tables 6/15 + 7: storage and time efficiency across sizes.
pub fn table6_7(rt: &Runtime, manifest: &Manifest, sizes: &[&str], cfg: &RunCfg) -> Result<()> {
    println!("\n=== Tables 6/15 + 7: efficiency (storage MB, time) ===");
    println!(
        "{:<12} {:<12} {:>10} {:>12} {:>14} {:>10}",
        "Model", "Method", "Params(MB)", "Quant time", "Finetune time", "Extra(%)"
    );
    let world = World::new(cfg.world_seed);
    for tag in sizes {
        let base = pretrained_base(rt, manifest, tag, cfg)?;
        let items = mmlu_set(&world, 4, cfg.seed); // tiny eval: efficiency only
        let arms = vec![
            Arm::fp16(),
            Arm { name: "Vanilla", ..Arm::qlora(4) },
            Arm::icq_only(4),
            Arm::iec_only(4),
            Arm::ir_qlora(4),
        ];
        let mut vanilla_ft: f64 = 0.0;
        for arm in arms {
            let r = run_arm(rt, manifest, tag, &base, arm, Dataset::AlpacaSyn, &items, cfg)?;
            let ft = r.finetune_time.as_secs_f64();
            if r.arm.name == "Vanilla" {
                vanilla_ft = ft;
            }
            let extra = if r.arm.method.uses_icq() && vanilla_ft > 0.0 {
                format!("{:>9.2}%", r.quantize_time.as_secs_f64() / vanilla_ft * 100.0)
            } else {
                "        -".into()
            };
            println!(
                "{:<12} {:<12} {:>10.2} {:>12} {:>14} {extra}",
                format!("nano-{tag}"),
                r.arm.name,
                r.storage_mb,
                fmt_duration(r.quantize_time),
                fmt_duration(r.finetune_time),
            );
        }
    }
    Ok(())
}

/// Table 8: SynCSQA (0-shot, 7 suites).
pub fn table8(rt: &Runtime, manifest: &Manifest, tag: &str, cfg: &RunCfg) -> Result<()> {
    println!("\n=== Table 8: SynCSQA accuracy (%) (NanoLLaMA-{tag}, Flan v2) ===");
    let world = World::new(cfg.world_seed);
    let base = pretrained_base(rt, manifest, tag, cfg)?;
    let items = csqa_set(&world, cfg.eval_per_group, cfg.seed);
    print!("{:<22} {:>5}", "Method", "#Bit");
    for (name, _, _) in CSQA_SUITES {
        print!(" {name:>10}");
    }
    println!(" {:>7}", "Avg.");
    for arm in main_arms(4) {
        let r = run_arm(rt, manifest, tag, &base, arm, Dataset::FlanSyn, &items, cfg)?;
        print!("{:<22} {:>5}", r.arm.name, r.arm.method.bits());
        for g in 0..CSQA_SUITES.len() {
            print!(" {:>10.1}", r.eval.group_accuracy(g) * 100.0);
        }
        println!(" {:>7.1}", r.eval.avg_accuracy() * 100.0);
    }
    Ok(())
}

/// Table 9: ultra-low bit-widths (2/3-bit), both datasets.
pub fn table9(rt: &Runtime, manifest: &Manifest, tag: &str, cfg: &RunCfg) -> Result<()> {
    println!("\n=== Table 9: 2/3-bit SynMMLU (NanoLLaMA-{tag}) ===");
    let world = World::new(cfg.world_seed);
    let base = pretrained_base(rt, manifest, tag, cfg)?;
    let items = mmlu_set(&world, cfg.eval_per_group, cfg.seed);
    mmlu_header("  data");
    // trimmed arm set per bit-width x dataset (full grid = 20 arms; the
    // omitted combinations run via `irqlora finetune --bits K --method M`)
    for k in [3u8, 2] {
        for dataset in [Dataset::AlpacaSyn, Dataset::FlanSyn] {
            let arms = vec![
                Arm { name: "NormalFloat", ..Arm::normalfloat(k) },
                Arm::qlora(k),
                Arm::ir_qlora(k),
            ];
            for arm in arms {
                let r = run_arm(rt, manifest, tag, &base, arm, dataset, &items, cfg)?;
                mmlu_row(&r, &format!("  {}", dataset.paper_name()));
            }
        }
    }
    Ok(())
}

/// Table 10: integer-quantizer variants.
pub fn table10(rt: &Runtime, manifest: &Manifest, tag: &str, cfg: &RunCfg) -> Result<()> {
    println!("\n=== Table 10: IR-QLoRA variants on the integer quantizer ===");
    let world = World::new(cfg.world_seed);
    let base = pretrained_base(rt, manifest, tag, cfg)?;
    let items = mmlu_set(&world, cfg.eval_per_group, cfg.seed);
    mmlu_header("");
    for arm in [Arm::fp16(), Arm::qalora(4), Arm::ir_qlora_int(4)] {
        let r = run_arm(rt, manifest, tag, &base, arm, Dataset::AlpacaSyn, &items, cfg)?;
        mmlu_row(&r, "");
    }
    Ok(())
}

/// Tables 11–13: NF codebook values (computed, asserted vs paper).
pub fn table_codebooks() {
    for (k, label) in [(2u8, "Table 11: NF2"), (3, "Table 12: NF3"), (4, "Table 13: NF4")] {
        println!("\n=== {label} ===");
        for (i, v) in nf::codebook(k).iter().enumerate() {
            println!("{i:>3}  {v:+.16}");
        }
    }
}

/// Figures 4/5: per-layer entropy of quantized projections, ICQ vs
/// vanilla. Prints one series per projection kind (Figure 5's panels);
/// the Key projection alone is Figure 4.
pub fn figures_4_5(rt: &Runtime, manifest: &Manifest, tag: &str, cfg: &RunCfg) -> Result<()> {
    println!("\n=== Figures 4/5: entropy of quantized linear projections (NanoLLaMA-{tag}) ===");
    let base = pretrained_base(rt, manifest, tag, cfg)?;
    let rows = crate::coordinator::quantize::entropy_by_projection(&base, 4);
    println!("{:<14} {:>10} {:>10} {:>8}", "projection", "vanilla", "ICQ", "gain");
    let mut by_kind: std::collections::BTreeMap<&str, Vec<(f64, f64)>> = Default::default();
    for (name, h0, h1) in &rows {
        println!("{name:<14} {h0:>10.4} {h1:>10.4} {:>+8.4}", h1 - h0);
        if let Some(kind) = crate::model::weights::proj_kind(name) {
            by_kind.entry(Box::leak(kind.to_string().into_boxed_str()))
                .or_default()
                .push((*h0, *h1));
        }
    }
    println!("\nper-projection-kind means (Figure 5 panels):");
    for (kind, vals) in by_kind {
        let n = vals.len() as f64;
        let h0: f64 = vals.iter().map(|v| v.0).sum::<f64>() / n;
        let h1: f64 = vals.iter().map(|v| v.1).sum::<f64>() / n;
        println!("  {kind:<4} vanilla {h0:.4}  ICQ {h1:.4}  gain {:+.4}", h1 - h0);
    }
    Ok(())
}

pub fn paper_analog(tag: &str) -> &'static str {
    match tag {
        "xs" => "7B",
        "s" => "13B",
        "m" => "30B",
        "l" => "65B",
        _ => "?",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analog_labels() {
        assert_eq!(paper_analog("xs"), "7B");
        assert_eq!(paper_analog("l"), "65B");
    }

    #[test]
    fn main_arm_list_matches_paper_rows() {
        let arms = main_arms(4);
        let names: Vec<&str> = arms.iter().map(|a| a.name).collect();
        assert_eq!(
            names,
            ["16-bit", "NormalFloat", "QLoRA w/ GPTQ", "QLoRA", "QA-LoRA", "IR-QLoRA"]
        );
    }

    #[test]
    fn codebook_table_prints() {
        table_codebooks(); // smoke: must not panic
    }
}
