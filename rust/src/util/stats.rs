//! Descriptive statistics over f32 slices: the ICQ search (median
//! initialization, entropy metric) and the evaluation harness both sit
//! on these primitives.

/// Arithmetic mean. Empty slices return 0.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64) as f32
}

/// Population standard deviation.
pub fn std(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt() as f32
}

/// Maximum of |x| over the slice. Empty slices return 0.
pub fn absmax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()))
}

/// Linear-interpolation quantile (same convention as numpy's default).
/// `q` in [0, 1]. Sorts a copy — use [`quantile_sorted`] in hot loops.
pub fn quantile(xs: &[f32], q: f32) -> f32 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile over an already-sorted slice.
pub fn quantile_sorted(sorted: &[f32], q: f32) -> f32 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q), "quantile q out of range: {q}");
    let pos = q as f64 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = (pos - lo as f64) as f32;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (0.5 quantile).
pub fn median(xs: &[f32]) -> f32 {
    quantile(xs, 0.5)
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute difference between two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .fold(0.0f32, |acc, (&x, &y)| acc.max((x - y).abs()))
}

/// Shannon entropy (bits) of a discrete histogram of counts.
/// Zero-count bins contribute nothing (lim p→0 of −p·log p = 0).
pub fn entropy_bits(counts: &[u32]) -> f64 {
    let total: u64 = counts.iter().map(|&c| c as u64).sum();
    if total == 0 {
        return 0.0;
    }
    let total_f = total as f64;
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total_f;
            h -= p * p.log2();
        }
    }
    h
}

/// Softmax over a slice (numerically stable).
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &v)| {
            if v > bv {
                (i, v)
            } else {
                (bi, bv)
            }
        })
        .0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std(&xs) - 1.1180340).abs() < 1e-5);
    }

    #[test]
    fn quantile_matches_numpy_convention() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-6);
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-6);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-6);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-6);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[4.0, 1.0, 2.0, 3.0]) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn entropy_uniform_is_log2_n() {
        assert!((entropy_bits(&[5, 5, 5, 5]) - 2.0).abs() < 1e-12);
        assert!((entropy_bits(&[1; 16]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_degenerate_is_zero() {
        assert_eq!(entropy_bits(&[10, 0, 0, 0]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
        assert_eq!(entropy_bits(&[0, 0]), 0.0);
    }

    #[test]
    fn entropy_monotone_in_spread() {
        // Flatter histograms have strictly larger entropy.
        let h1 = entropy_bits(&[16, 0, 0, 0]);
        let h2 = entropy_bits(&[8, 8, 0, 0]);
        let h3 = entropy_bits(&[4, 4, 4, 4]);
        assert!(h1 < h2 && h2 < h3);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn absmax_and_argmax() {
        assert_eq!(absmax(&[-3.0, 2.0]), 3.0);
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }
}
