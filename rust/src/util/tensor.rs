//! A minimal dense f32 tensor: shape + contiguous row-major data.
//!
//! This is deliberately small — the heavy math runs inside AOT-compiled
//! XLA executables. The Rust side needs tensors only for weight
//! generation, quantization, checkpointing, oracles in tests, and the
//! handful of host-side ops the evaluator uses (matmul for scoring
//! oracles, transpose for layout fixes).

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Build from shape and data; panics if the element count mismatches.
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape.to_vec();
        self
    }

    /// 2-D element access.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row slice of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Matrix transpose (2-D only).
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.rank(), 2, "transpose needs rank 2");
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor { shape: vec![c, r], data: out }
    }

    /// Naive matmul: (m,k) x (k,n) -> (m,n). Test oracle, not a hot path.
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &rhs.data[p * n..(p + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * row[j];
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Elementwise map.
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Elementwise addition.
    pub fn add(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        let data = self
            .data
            .iter()
            .zip(&rhs.data)
            .map(|(&a, &b)| a + b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Multiply by scalar.
    pub fn scale(&self, s: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x * s).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        let t = t.reshape(&[3, 2]);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let id = Tensor::new(&[2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_involutive() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at2(2, 1), 6.0);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::full(&[4], 2.0);
        let b = a.scale(3.0);
        assert_eq!(b.data(), &[6.0; 4]);
        let c = a.add(&b);
        assert_eq!(c.data(), &[8.0; 4]);
        let d = c.map(|x| x / 2.0);
        assert_eq!(d.data(), &[4.0; 4]);
    }
}
