//! Foundational utilities: deterministic RNG, special functions,
//! statistics, a small tensor type, half-precision codec, threading
//! helpers, and timers. Everything above `util` builds on these.

pub mod env;
pub mod f16;
pub mod hash;
pub mod mathfn;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod threads;
pub mod timer;

pub use rng::Rng;
pub use tensor::Tensor;
