//! Non-cryptographic hashing shared across the crate: FNV-1a, used by
//! checkpoint integrity checksums (`model::checkpoint`) and the
//! serving pool's consistent adapter→worker assignment
//! (`coordinator::pool::home_worker`). Deterministic across processes
//! and runs — no per-process seed — which is exactly the property both
//! call sites rely on.

/// The FNV-1a 64-bit offset basis (the initial `state`).
pub const FNV1A_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a 64-bit state. Chainable: feed the
/// result back as `state` to hash a sequence of byte blocks.
pub fn fnv1a(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // standard FNV-1a test vectors (seeded with the offset basis)
        assert_eq!(fnv1a(FNV1A_SEED, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV1A_SEED, b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV1A_SEED, b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv1a_chains() {
        let whole = fnv1a(FNV1A_SEED, b"hello world");
        let chained = fnv1a(fnv1a(FNV1A_SEED, b"hello "), b"world");
        assert_eq!(whole, chained);
        assert_ne!(whole, fnv1a(FNV1A_SEED, b"hello_world"));
    }
}
