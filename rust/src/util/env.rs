//! Central registry of `IRQLORA_*` environment knobs.
//!
//! Every knob the process reads is declared ONCE here: its name, its
//! default, what it means, and the typed parser that interprets it.
//! The per-module resolvers (`util::threads::worker_count`,
//! `coordinator::pool::serve_workers`, …) delegate here, so the
//! [`knobs`] table is the source of truth the README env-knob table
//! and the `irqlora backends` capability output are generated from —
//! a knob that exists in code but not in this table is a bug, and the
//! README-drift test in this module enforces the reverse direction.
//!
//! Parsing convention (uniform across knobs): positive values are
//! honored, zero/garbage is ignored and falls back to the default —
//! except where zero is meaningful (`IRQLORA_PARK_AGE_MS`) or the
//! knob is an off-switch (`IRQLORA_SERVE_STEAL`). All parsers are
//! pure functions of the string value so they are testable without
//! mutating the process-global environment (tests run in parallel).

use std::time::Duration;

/// One declared environment knob.
#[derive(Clone, Copy, Debug)]
pub struct Knob {
    /// Environment variable name (`IRQLORA_*`).
    pub name: &'static str,
    /// Human-readable default (what an unset knob resolves to).
    pub default: &'static str,
    /// One-line meaning, suitable for a generated docs table.
    pub meaning: &'static str,
}

/// Default pool worker count (`IRQLORA_SERVE_WORKERS` unset).
pub const DEFAULT_SERVE_WORKERS: usize = 2;
/// Default pool-wide parked-overflow capacity (`IRQLORA_PARK_BOUND`
/// unset).
pub const DEFAULT_PARK_BOUND: usize = 1024;
/// Default parked-request aging threshold in milliseconds
/// (`IRQLORA_PARK_AGE_MS` unset).
pub const DEFAULT_PARK_AGE_MS: u64 = 20;
/// Default merged-weight (host) cache capacity
/// (`IRQLORA_ADAPTER_CACHE` unset).
pub const DEFAULT_ADAPTER_CACHE: usize = 8;
/// Default serving backend name (`IRQLORA_SERVE_BACKEND` unset).
pub const DEFAULT_SERVE_BACKEND: &str = "reference";
/// Default per-stream decode-step ceiling (`IRQLORA_STREAM_MAX_STEPS`
/// unset).
pub const DEFAULT_STREAM_MAX_STEPS: usize = 64;
/// Default GEMM column-stripe width (`IRQLORA_GEMM_BLOCK` unset).
pub const DEFAULT_GEMM_BLOCK: usize = 64;
/// Default multiply-add count below which the GEMM kernels skip the
/// thread pool (`IRQLORA_GEMM_SERIAL_BELOW` unset).
pub const DEFAULT_GEMM_SERIAL_BELOW: usize = 8192;

/// Cap on `IRQLORA_THREADS`.
pub const THREADS_CAP: usize = 256;
/// Cap on `IRQLORA_SERVE_WORKERS` (mirrors the `PoolConfig` clamp).
pub const SERVE_WORKERS_CAP: usize = 64;
/// Cap on the host and device cache knobs.
pub const CACHE_CAP: usize = 4096;
/// Cap on `IRQLORA_PARK_BOUND` — beyond this the bound is no longer a
/// memory guarantee.
pub const PARK_BOUND_CAP: usize = 1 << 20;
/// Cap on `IRQLORA_PARK_AGE_MS` (10 minutes).
pub const PARK_AGE_CAP_MS: u64 = 600_000;
/// Cap on `IRQLORA_STREAM_MAX_STEPS` — a stream cannot outlast the
/// longest supported sequence anyway.
pub const STREAM_MAX_STEPS_CAP: usize = 4096;
/// Cap on `IRQLORA_GEMM_BLOCK` — the blocked kernel keeps one f64
/// accumulator per stripe column on the stack, sized to this cap
/// (`kernels::GEMM_BLOCK_MAX` mirrors it).
pub const GEMM_BLOCK_CAP: usize = 256;
/// Cap on `IRQLORA_GEMM_SERIAL_BELOW`.
pub const GEMM_SERIAL_BELOW_CAP: usize = 1 << 30;

/// The full knob table, one entry per environment variable the
/// process reads. Order matches the README table.
pub fn knobs() -> &'static [Knob] {
    const KNOBS: &[Knob] = &[
        Knob {
            name: "IRQLORA_THREADS",
            default: "autodetect (<= 32)",
            meaning: "Worker threads for parallel quantize/pack/profile paths. \
                      Pin for reproducible benches.",
        },
        Knob {
            name: "IRQLORA_GEMM_BLOCK",
            default: "64",
            meaning: "Column-stripe width for the blocked dense GEMM kernel \
                      (`kernels::gemm_f32`), capped at 256. Every width produces \
                      bit-identical output (the k-reduction order never changes); \
                      tune for cache footprint only.",
        },
        Knob {
            name: "IRQLORA_GEMM_SERIAL_BELOW",
            default: "8192",
            meaning: "Multiply-add count under which the GEMM kernels skip the \
                      thread pool and run serially — tiny shapes cost more to \
                      dispatch than to compute.",
        },
        Knob {
            name: "IRQLORA_SERVE_BACKEND",
            default: "reference",
            meaning: "Default HAL serving backend when the CLI/tests do not name one \
                      (`irqlora backends` lists what is registered).",
        },
        Knob {
            name: "IRQLORA_SERVE_WORKERS",
            default: "2",
            meaning: "`ServerPool` worker count when `PoolConfig.workers == 0`.",
        },
        Knob {
            name: "IRQLORA_SERVE_STEAL",
            default: "on (`0` = off)",
            meaning: "Work-stealing scheduler kill switch; off restores the legacy \
                      push-spill scheduler.",
        },
        Knob {
            name: "IRQLORA_PARK_BOUND",
            default: "1024",
            meaning: "Max requests parked in the overflow queues, pool-wide. A full \
                      overflow refuses new work with `ServeError::Overloaded` instead \
                      of queueing without bound.",
        },
        Knob {
            name: "IRQLORA_PARK_AGE_MS",
            default: "20",
            meaning: "Age at which a parked request is PROMOTED: workers poll aged \
                      parked work ahead of fresh channel arrivals at the start of \
                      each admission pass, so a saturated home cannot starve its \
                      overflow. (Expiry is separate — only an explicit per-request \
                      deadline sheds with `DeadlineExceeded`.)",
        },
        Knob {
            name: "IRQLORA_STREAM_MAX_STEPS",
            default: "64",
            meaning: "Max decode steps one `submit_stream` request may ask for; \
                      larger step counts are rejected at submit time (the prompt \
                      must also leave room: `prompt + steps - 1 <= seq`).",
        },
        Knob {
            name: "IRQLORA_ADAPTER_CACHE",
            default: "8",
            meaning: "Registry LRU capacity for merged serving weights (host RAM).",
        },
        Knob {
            name: "IRQLORA_DEVICE_CACHE",
            default: "= adapter cache",
            meaning: "Per-worker device-buffer LRU for uploaded adapters (device \
                      memory — budget separately when raising the host cache).",
        },
        Knob {
            name: "IRQLORA_BIT_BUDGET",
            default: "—",
            meaning: "Planner target, average packed code bits/weight (e.g. `3.2`).",
        },
        Knob {
            name: "IRQLORA_BIT_FLOOR",
            default: "2",
            meaning: "Planner per-tensor minimum bit-width.",
        },
        Knob {
            name: "IRQLORA_BIT_CEIL",
            default: "8",
            meaning: "Planner per-tensor maximum bit-width.",
        },
        Knob {
            name: "IRQLORA_BENCH_QUICK",
            default: "off",
            meaning: "Benches run one measured iteration (smoke mode).",
        },
        Knob {
            name: "IRQLORA_BENCH_JSON",
            default: "`BENCH_quant.json`",
            meaning: "Redirect bench row output (verify.sh points it at a scratch \
                      file so smoke noise never lands in the tracked file).",
        },
        Knob {
            name: "IRQLORA_TELEMETRY",
            default: "off",
            meaning: "Enable telemetry recording (`telemetry::global()`). Unset/`0`: \
                      every handle is a compiled-in no-op — zero allocation, zero \
                      atomics on the hot path.",
        },
        Knob {
            name: "IRQLORA_TELEMETRY_JSONL",
            default: "—",
            meaning: "Append periodic + final telemetry snapshots to this JSONL path \
                      (only with `IRQLORA_TELEMETRY=1`); `irqlora stats FILE` renders \
                      the last snapshot.",
        },
    ];
    KNOBS
}

// ---------------------------------------------------------------------------
// Pure parsers (no env access — testable without global mutation).
// ---------------------------------------------------------------------------

/// Interpret a positive-count knob value: integers `>= 1` are honored
/// (capped at `cap`); zero and garbage are ignored.
pub fn parse_count(v: &str, cap: usize) -> Option<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n.min(cap)),
        _ => None,
    }
}

/// Interpret an on/off kill-switch value: `0` / `false` / `off` /
/// `no` (case-insensitive) mean off; anything else means on.
pub fn parse_off_flag(v: &str) -> bool {
    !matches!(
        v.trim().to_ascii_lowercase().as_str(),
        "0" | "false" | "off" | "no"
    )
}

/// Interpret a millisecond-count knob value: a non-negative integer
/// (capped at `cap_ms`; `0` is meaningful); garbage is ignored.
pub fn parse_ms(v: &str, cap_ms: u64) -> Option<Duration> {
    v.trim()
        .parse::<u64>()
        .ok()
        .map(|ms| Duration::from_millis(ms.min(cap_ms)))
}

/// Interpret a positive-float knob value (the planner bit budget):
/// positive finite numbers are honored; garbage is ignored.
pub fn parse_f64_pos(v: &str) -> Option<f64> {
    match v.trim().parse::<f64>() {
        Ok(b) if b.is_finite() && b > 0.0 => Some(b),
        _ => None,
    }
}

/// Interpret a bit-width knob value: integers in 1..=8.
pub fn parse_k(v: &str) -> Option<u8> {
    match v.trim().parse::<u8>() {
        Ok(k) if (1..=8).contains(&k) => Some(k),
        _ => None,
    }
}

/// Whether a quick-mode flag value means "on": any non-empty value
/// other than `0`.
pub fn parse_quick(v: Option<&str>) -> bool {
    matches!(v, Some(s) if !s.is_empty() && s != "0")
}

/// Interpret a backend-name knob value: a trimmed, non-empty name.
pub fn parse_name(v: &str) -> Option<String> {
    let t = v.trim();
    if t.is_empty() {
        None
    } else {
        Some(t.to_string())
    }
}

// ---------------------------------------------------------------------------
// Typed accessors — the ONLY `std::env::var("IRQLORA_*")` call sites.
// ---------------------------------------------------------------------------

fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// `IRQLORA_THREADS` override, if set and valid.
pub fn threads_override() -> Option<usize> {
    var("IRQLORA_THREADS").and_then(|v| parse_count(&v, THREADS_CAP))
}

/// `IRQLORA_SERVE_WORKERS`, else [`DEFAULT_SERVE_WORKERS`].
pub fn serve_workers() -> usize {
    var("IRQLORA_SERVE_WORKERS")
        .and_then(|v| parse_count(&v, SERVE_WORKERS_CAP))
        .unwrap_or(DEFAULT_SERVE_WORKERS)
}

/// `IRQLORA_SERVE_STEAL` kill switch (unset means on).
pub fn serve_steal() -> bool {
    var("IRQLORA_SERVE_STEAL")
        .map(|v| parse_off_flag(&v))
        .unwrap_or(true)
}

/// `IRQLORA_PARK_BOUND`, else [`DEFAULT_PARK_BOUND`].
pub fn park_bound() -> usize {
    var("IRQLORA_PARK_BOUND")
        .and_then(|v| parse_count(&v, PARK_BOUND_CAP))
        .unwrap_or(DEFAULT_PARK_BOUND)
}

/// `IRQLORA_PARK_AGE_MS`, else [`DEFAULT_PARK_AGE_MS`].
pub fn park_age() -> Duration {
    var("IRQLORA_PARK_AGE_MS")
        .and_then(|v| parse_ms(&v, PARK_AGE_CAP_MS))
        .unwrap_or(Duration::from_millis(DEFAULT_PARK_AGE_MS))
}

/// `IRQLORA_STREAM_MAX_STEPS`, else [`DEFAULT_STREAM_MAX_STEPS`].
pub fn stream_max_steps() -> usize {
    var("IRQLORA_STREAM_MAX_STEPS")
        .and_then(|v| parse_count(&v, STREAM_MAX_STEPS_CAP))
        .unwrap_or(DEFAULT_STREAM_MAX_STEPS)
}

/// `IRQLORA_GEMM_BLOCK`, else [`DEFAULT_GEMM_BLOCK`].
pub fn gemm_block() -> usize {
    var("IRQLORA_GEMM_BLOCK")
        .and_then(|v| parse_count(&v, GEMM_BLOCK_CAP))
        .unwrap_or(DEFAULT_GEMM_BLOCK)
}

/// `IRQLORA_GEMM_SERIAL_BELOW`, else [`DEFAULT_GEMM_SERIAL_BELOW`].
pub fn gemm_serial_below() -> usize {
    var("IRQLORA_GEMM_SERIAL_BELOW")
        .and_then(|v| parse_count(&v, GEMM_SERIAL_BELOW_CAP))
        .unwrap_or(DEFAULT_GEMM_SERIAL_BELOW)
}

/// `IRQLORA_ADAPTER_CACHE`, else [`DEFAULT_ADAPTER_CACHE`].
pub fn adapter_cache() -> usize {
    var("IRQLORA_ADAPTER_CACHE")
        .and_then(|v| parse_count(&v, CACHE_CAP))
        .unwrap_or(DEFAULT_ADAPTER_CACHE)
}

/// `IRQLORA_DEVICE_CACHE`, else the host merged-cache capacity
/// ([`adapter_cache`]) — one device slot per host-cached merge.
pub fn device_cache() -> usize {
    var("IRQLORA_DEVICE_CACHE")
        .and_then(|v| parse_count(&v, CACHE_CAP))
        .unwrap_or_else(adapter_cache)
}

/// `IRQLORA_BIT_BUDGET` override, if set and valid.
pub fn bit_budget() -> Option<f64> {
    var("IRQLORA_BIT_BUDGET").and_then(|v| parse_f64_pos(&v))
}

/// `IRQLORA_BIT_FLOOR` override, if set and valid.
pub fn bit_floor() -> Option<u8> {
    var("IRQLORA_BIT_FLOOR").and_then(|v| parse_k(&v))
}

/// `IRQLORA_BIT_CEIL` override, if set and valid.
pub fn bit_ceil() -> Option<u8> {
    var("IRQLORA_BIT_CEIL").and_then(|v| parse_k(&v))
}

/// `IRQLORA_BENCH_QUICK` quick-mode flag.
pub fn bench_quick() -> bool {
    parse_quick(var("IRQLORA_BENCH_QUICK").as_deref())
}

/// `IRQLORA_BENCH_JSON` output-path override, if set.
pub fn bench_json() -> Option<String> {
    var("IRQLORA_BENCH_JSON")
}

/// `IRQLORA_TELEMETRY` recording flag (unset/`0`/empty means off —
/// same convention as the quick-mode flag).
pub fn telemetry_enabled() -> bool {
    parse_quick(var("IRQLORA_TELEMETRY").as_deref())
}

/// `IRQLORA_TELEMETRY_JSONL` snapshot path, if set and non-empty.
pub fn telemetry_jsonl() -> Option<String> {
    var("IRQLORA_TELEMETRY_JSONL").and_then(|v| parse_name(&v))
}

/// `IRQLORA_SERVE_BACKEND`, else [`DEFAULT_SERVE_BACKEND`]. The CLI
/// `--backend` flag and test batteries consult this to pick a HAL
/// backend when none is named explicitly.
pub fn serve_backend() -> String {
    serve_backend_override().unwrap_or_else(|| DEFAULT_SERVE_BACKEND.to_string())
}

/// `IRQLORA_SERVE_BACKEND` only when explicitly set — the CLI uses
/// this to tell "operator pinned a backend" apart from the default
/// (where `irqlora serve` keeps its legacy artifacts-then-fallback
/// auto-selection).
pub fn serve_backend_override() -> Option<String> {
    var("IRQLORA_SERVE_BACKEND").and_then(|v| parse_name(&v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_parser_contract() {
        assert_eq!(parse_count("2", 64), Some(2));
        assert_eq!(parse_count(" 8 ", 64), Some(8));
        assert_eq!(parse_count("99999", 64), Some(64)); // capped
        assert_eq!(parse_count("0", 64), None); // zero ignored
        assert_eq!(parse_count("garbage", 64), None);
        assert_eq!(parse_count("", 64), None);
    }

    #[test]
    fn off_flag_parser_contract() {
        for off in ["0", "false", "off", "no", " OFF ", "False"] {
            assert!(!parse_off_flag(off), "{off:?} should mean off");
        }
        for on in ["1", "true", "on", "yes", "", "anything"] {
            assert!(parse_off_flag(on), "{on:?} should mean on");
        }
    }

    #[test]
    fn ms_parser_keeps_zero_meaningful() {
        assert_eq!(parse_ms("0", 600_000), Some(Duration::from_millis(0)));
        assert_eq!(parse_ms("250", 600_000), Some(Duration::from_millis(250)));
        assert_eq!(
            parse_ms("999999999", 600_000),
            Some(Duration::from_millis(600_000))
        );
        assert_eq!(parse_ms("nope", 600_000), None);
    }

    #[test]
    fn float_and_k_parsers() {
        assert_eq!(parse_f64_pos("3.2"), Some(3.2));
        assert_eq!(parse_f64_pos("0"), None);
        assert_eq!(parse_f64_pos("-1"), None);
        assert_eq!(parse_f64_pos("inf"), None);
        assert_eq!(parse_k("4"), Some(4));
        assert_eq!(parse_k("0"), None);
        assert_eq!(parse_k("9"), None);
    }

    #[test]
    fn quick_and_name_parsers() {
        assert!(!parse_quick(None));
        assert!(!parse_quick(Some("")));
        assert!(!parse_quick(Some("0")));
        assert!(parse_quick(Some("1")));
        assert_eq!(parse_name("  native "), Some("native".to_string()));
        assert_eq!(parse_name("   "), None);
    }

    #[test]
    fn knob_table_is_complete_and_unique() {
        let ks = knobs();
        assert!(ks.len() >= 16);
        let mut names: Vec<&str> = ks.iter().map(|k| k.name).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate knob declared");
        for k in ks {
            assert!(k.name.starts_with("IRQLORA_"), "{} not namespaced", k.name);
            assert!(!k.meaning.is_empty());
        }
        // every knob this module resolves is declared in the table
        for resolved in [
            "IRQLORA_THREADS",
            "IRQLORA_SERVE_BACKEND",
            "IRQLORA_SERVE_WORKERS",
            "IRQLORA_SERVE_STEAL",
            "IRQLORA_PARK_BOUND",
            "IRQLORA_PARK_AGE_MS",
            "IRQLORA_STREAM_MAX_STEPS",
            "IRQLORA_GEMM_BLOCK",
            "IRQLORA_GEMM_SERIAL_BELOW",
            "IRQLORA_ADAPTER_CACHE",
            "IRQLORA_DEVICE_CACHE",
            "IRQLORA_BIT_BUDGET",
            "IRQLORA_BIT_FLOOR",
            "IRQLORA_BIT_CEIL",
            "IRQLORA_BENCH_QUICK",
            "IRQLORA_BENCH_JSON",
            "IRQLORA_TELEMETRY",
            "IRQLORA_TELEMETRY_JSONL",
        ] {
            assert!(
                ks.iter().any(|k| k.name == resolved),
                "{resolved} missing from knobs()"
            );
        }
    }

    #[test]
    fn readme_documents_every_knob() {
        // Docs can't drift from code: the README env-knob table must
        // mention every declared knob by name.
        let readme = include_str!("../../../README.md");
        for k in knobs() {
            assert!(
                readme.contains(k.name),
                "README.md does not document {}",
                k.name
            );
        }
    }
}
