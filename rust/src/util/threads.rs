//! Tiny data-parallel helpers on std::thread::scope.
//!
//! The ICQ τ search is embarrassingly parallel across quantization
//! blocks; rayon is not in the offline vendor set, so this module
//! provides the two primitives the pipeline needs: parallel map over an
//! index range with static chunking, and a mutable-chunks variant.

/// Number of worker threads to use (available_parallelism, capped).
pub fn worker_count() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Parallel map `f(i)` for `i in 0..n`, preserving order.
///
/// `f` must be `Sync` (shared across workers). Falls back to the serial
/// path for small `n` where spawn overhead would dominate.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count();
    if n < 64 || workers <= 1 {
        return (0..n).map(&f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let slots = out.as_mut_slice();

    std::thread::scope(|scope| {
        // Hand each worker a disjoint sub-slice of the output.
        let mut rest = slots;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let begin = start;
            let fref = &f;
            handles.push(scope.spawn(move || {
                for (k, slot) in head.iter_mut().enumerate() {
                    *slot = Some(fref(begin + k));
                }
            }));
            start += take;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });

    out.into_iter().map(|o| o.expect("slot unfilled")).collect()
}

/// Parallel for-each over mutable, equally-sized chunks of a slice.
/// `f(chunk_index, chunk)` runs on worker threads.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_size: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_size > 0);
    let n_chunks = data.len().div_ceil(chunk_size);
    if n_chunks <= 1 || worker_count() <= 1 {
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(i, c);
        }
        return;
    }
    let workers = worker_count().min(n_chunks);
    let per_worker = n_chunks.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut chunk_idx = 0usize;
        let fref = &f;
        while !rest.is_empty() {
            let take = (per_worker * chunk_size).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = chunk_idx;
            scope.spawn(move || {
                for (k, c) in head.chunks_mut(chunk_size).enumerate() {
                    fref(base + k, c);
                }
            });
            chunk_idx += take.div_ceil(chunk_size);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(1000, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_small_n() {
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut v = vec![0u32; 1037];
        par_chunks_mut(&mut v, 64, |ci, c| {
            for x in c.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        assert_eq!(v[0], 1);
        assert_eq!(v[64], 2);
        assert_eq!(*v.last().unwrap(), 1037u32.div_ceil(64));
    }

    #[test]
    fn par_chunks_uneven_tail() {
        let mut v = vec![1.0f32; 130];
        par_chunks_mut(&mut v, 64, |_, c| {
            let s: f32 = c.iter().sum();
            c[0] = s;
        });
        assert_eq!(v[0], 64.0);
        assert_eq!(v[128], 2.0);
    }
}
