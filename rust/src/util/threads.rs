//! Tiny data-parallel helpers on std::thread::scope.
//!
//! The quantization storage pipeline (blockwise quant/dequant, bit
//! packing, double quantization, the ICQ τ search) is embarrassingly
//! parallel across blocks; rayon is not in the offline vendor set, so
//! this module provides the primitives the pipeline needs: parallel map
//! over an index range with static chunking, and a mutable-chunks
//! variant. Both come in a default-threshold flavor ([`par_map`],
//! [`par_chunks_mut`]) and a `_with` flavor whose serial-fallback
//! threshold is tunable per call site — a τ search over 8 blocks is
//! worth fanning out (201 entropy evaluations per block), while an
//! 8-block memcpy-ish dequant is not.

/// Default `min_parallel` for [`par_map`]: below this many items the
/// spawn overhead dominates for cheap per-item work.
pub const DEFAULT_MIN_PARALLEL: usize = 64;

/// Number of worker threads to use. Honors the `IRQLORA_THREADS`
/// environment override (reproducible benches, CI determinism, read
/// through `util::env`); falls back to `available_parallelism`,
/// capped at 32.
pub fn worker_count() -> usize {
    if let Some(n) = crate::util::env::threads_override() {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Interpret an `IRQLORA_THREADS` value: positive integers are honored
/// (capped at 256); zero and garbage are ignored (autodetect). The
/// parse itself lives in `util::env` with the other knobs; this
/// wrapper keeps the historical contract tests anchored here.
#[cfg(test)]
fn parse_thread_override(v: &str) -> Option<usize> {
    crate::util::env::parse_count(v, crate::util::env::THREADS_CAP)
}

/// Parallel map `f(i)` for `i in 0..n`, preserving order, with the
/// default serial-fallback threshold ([`DEFAULT_MIN_PARALLEL`]).
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_with(n, DEFAULT_MIN_PARALLEL, f)
}

/// Parallel map `f(i)` for `i in 0..n`, preserving order.
///
/// `f` must be `Sync` (shared across workers). Falls back to the serial
/// path when `n < min_parallel` — pick `min_parallel` per call site:
/// small for expensive `f` (e.g. the ICQ τ search), large for cheap
/// per-item work where spawn overhead would dominate.
pub fn par_map_with<T, F>(n: usize, min_parallel: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count();
    if n < min_parallel.max(2) || workers <= 1 {
        return (0..n).map(&f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let slots = out.as_mut_slice();

    std::thread::scope(|scope| {
        // Hand each worker a disjoint sub-slice of the output.
        let mut rest = slots;
        let mut start = 0usize;
        let mut handles = Vec::new();
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let begin = start;
            let fref = &f;
            handles.push(scope.spawn(move || {
                for (k, slot) in head.iter_mut().enumerate() {
                    *slot = Some(fref(begin + k));
                }
            }));
            start += take;
        }
        for h in handles {
            h.join().expect("par_map worker panicked");
        }
    });

    out.into_iter().map(|o| o.expect("slot unfilled")).collect()
}

/// Parallel for-each over mutable, equally-sized chunks of a slice
/// with the default fallback (serial only when there is one chunk).
/// `f(chunk_index, chunk)` runs on worker threads.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_size: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with(data, chunk_size, 2, f)
}

/// Parallel for-each over mutable, equally-sized chunks of a slice.
/// `f(chunk_index, chunk)` runs on worker threads; the call stays
/// serial when there are fewer than `min_chunks` chunks (tunable per
/// call site, min 2).
pub fn par_chunks_mut_with<T, F>(data: &mut [T], chunk_size: usize, min_chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_size > 0);
    let n_chunks = data.len().div_ceil(chunk_size);
    if n_chunks < min_chunks.max(2) || worker_count() <= 1 {
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(i, c);
        }
        return;
    }
    let workers = worker_count().min(n_chunks);
    let per_worker = n_chunks.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut chunk_idx = 0usize;
        let fref = &f;
        while !rest.is_empty() {
            let take = (per_worker * chunk_size).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = chunk_idx;
            scope.spawn(move || {
                for (k, c) in head.chunks_mut(chunk_size).enumerate() {
                    fref(base + k, c);
                }
            });
            chunk_idx += take.div_ceil(chunk_size);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(1000, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_small_n() {
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_map_with_low_threshold_still_correct() {
        // min_parallel = 2 forces the parallel path even for tiny n
        let got = par_map_with(5, 2, |i| i * 3);
        assert_eq!(got, vec![0, 3, 6, 9, 12]);
        // threshold larger than n: serial path
        let got = par_map_with(5, 100, |i| i * 3);
        assert_eq!(got, vec![0, 3, 6, 9, 12]);
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut v = vec![0u32; 1037];
        par_chunks_mut(&mut v, 64, |ci, c| {
            for x in c.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        assert_eq!(v[0], 1);
        assert_eq!(v[64], 2);
        assert_eq!(*v.last().unwrap(), 1037u32.div_ceil(64));
    }

    #[test]
    fn par_chunks_uneven_tail() {
        let mut v = vec![1.0f32; 130];
        par_chunks_mut(&mut v, 64, |_, c| {
            let s: f32 = c.iter().sum();
            c[0] = s;
        });
        assert_eq!(v[0], 64.0);
        assert_eq!(v[128], 2.0);
    }

    #[test]
    fn par_chunks_mut_with_high_threshold_serial() {
        // min_chunks above the chunk count: must still process all
        let mut v = vec![0u8; 100];
        par_chunks_mut_with(&mut v, 10, 1000, |ci, c| {
            for x in c.iter_mut() {
                *x = ci as u8;
            }
        });
        assert_eq!(v[95], 9);
    }

    #[test]
    fn env_thread_override() {
        // the override interpretation is tested through the pure
        // helper; worker_count() itself is only smoke-checked so the
        // test never mutates the process-global env (tests run in
        // parallel and verify.sh pins IRQLORA_THREADS for determinism).
        assert_eq!(parse_thread_override("2"), Some(2));
        assert_eq!(parse_thread_override(" 8 "), Some(8));
        assert_eq!(parse_thread_override("99999"), Some(256)); // capped
        assert_eq!(parse_thread_override("not-a-number"), None);
        assert_eq!(parse_thread_override("0"), None); // zero is ignored
        assert_eq!(parse_thread_override(""), None);
        assert!(worker_count() >= 1);
    }
}
