//! Special functions needed by the quantization stack: the error
//! function, its inverse, and the standard normal CDF / quantile
//! function Q⁻¹ used to construct NormalFloat codebooks (paper Eq. 2).
//!
//! Implementations are double-precision rational approximations that
//! are accurate far beyond what 2–4 bit codebook construction needs
//! (|Δ| < 1e-9 over the domain we use) and match the SciPy values the
//! original QLoRA codebase relied on to the printed precision of the
//! paper's Tables 11–13.

/// Error function, |err| < 1.2e-7 (Abramowitz–Stegun 7.1.26 refined via
/// the W. J. Cody rational approximation).
pub fn erf(x: f64) -> f64 {
    // Use the complementary-error-function route for better tail accuracy.
    if x >= 0.0 {
        1.0 - erfc_pos(x)
    } else {
        erfc_pos(-x) - 1.0
    }
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    if x >= 0.0 {
        erfc_pos(x)
    } else {
        2.0 - erfc_pos(-x)
    }
}

/// erfc for x >= 0 — rational approximation (Numerical Recipes erfc
/// with |rel err| < 1.2e-7, adequate: codebooks round to f32).
fn erfc_pos(x: f64) -> f64 {
    debug_assert!(x >= 0.0);
    let t = 1.0 / (1.0 + 0.5 * x);
    let poly = -x * x - 1.26551223
        + t * (1.00002368
            + t * (0.37409196
                + t * (0.09678418
                    + t * (-0.18628806
                        + t * (0.27886807
                            + t * (-1.13520398
                                + t * (1.48851587
                                    + t * (-0.82215223 + t * 0.17087277))))))));
    t * poly.exp()
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Inverse of the standard normal CDF (the quantile function Q used in
/// Eq. 2 of the paper). Acklam's algorithm + one Halley refinement step
/// against [`norm_cdf`]; overall |err| < ~2e-7 (bounded by the erfc
/// approximation), far beyond f32 codebook needs.
pub fn norm_ppf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_ppf domain is (0,1), got {p}");
    if p == 0.5 {
        return 0.0;
    }

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley step against the exact CDF to polish.
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Inverse error function via norm_ppf.
pub fn erfinv(y: f64) -> f64 {
    assert!(y > -1.0 && y < 1.0, "erfinv domain is (-1,1), got {y}");
    norm_ppf((y + 1.0) / 2.0) / std::f64::consts::SQRT_2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // Reference values from standard tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 1e-7, "erf({x})={} want {want}", erf(x));
        }
    }

    #[test]
    fn cdf_ppf_roundtrip() {
        for i in 1..200 {
            let p = i as f64 / 200.0;
            let x = norm_ppf(p);
            // bounded by the ~1.2e-7 relative accuracy of the erfc approx
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p}");
        }
    }

    #[test]
    fn ppf_reference_values() {
        // SciPy scipy.stats.norm.ppf reference values.
        assert!((norm_ppf(0.5) - 0.0).abs() < 1e-12);
        assert!((norm_ppf(0.975) - 1.959963984540054).abs() < 5e-7);
        assert!((norm_ppf(0.8) - 0.8416212335729143).abs() < 5e-7);
        assert!((norm_ppf(0.0107) - (-2.300851965340215)).abs() < 5e-7);
    }

    #[test]
    fn erfinv_roundtrip() {
        for i in 1..100 {
            let y = -0.99 + 1.98 * (i as f64) / 100.0;
            assert!((erf(erfinv(y)) - y).abs() < 1e-7, "y={y}");
        }
    }

    #[test]
    #[should_panic]
    fn ppf_rejects_zero() {
        norm_ppf(0.0);
    }
}
