//! IEEE-754 binary16 (half precision) codec.
//!
//! The paper stores double-quantization scales as FP16 (`s₂^FP16`,
//! `τ₂^FP16`); storage accounting (Tables 6/15) and the emulated
//! double-quantization pipeline both need a faithful f32 ⇄ f16
//! round-trip, including subnormals and rounding-to-nearest-even.

/// Encode an f32 into binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }

    // Re-bias: f32 exp bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal range in f16.
        let mut m = mant >> 13; // keep 10 bits
        let rem = mant & 0x1FFF;
        // round to nearest even
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut e = (unbiased + 15) as u32;
        if m == 0x400 {
            m = 0;
            e += 1;
            if e >= 31 {
                return sign | 0x7C00;
            }
        }
        sign | ((e as u16) << 10) | m as u16
    } else if unbiased >= -25 {
        // Subnormal in f16.
        let full = mant | 0x80_0000; // implicit 1
        let shift = (-14 - unbiased) as u32 + 13;
        let mut m = full >> shift;
        let rem_mask = (1u32 << shift) - 1;
        let rem = full & rem_mask;
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1;
        }
        sign | m as u16
    } else {
        sign // underflow to signed zero
    }
}

/// Decode binary16 bits into f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            let exp32 = (e + 1 - 15 + 127) as u32;
            sign | (exp32 << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (quantize-dequantize).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.099976] {
            let y = round_f16(x);
            assert!(
                (x - y).abs() <= x.abs() * 1e-3 + 1e-7,
                "{x} -> {y}"
            );
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x3555), 0.33325195);
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00);
        assert!(f16_bits_to_f32(0x7C00).is_infinite());
        assert_eq!(f32_to_f16_bits(-1e6), 0xFC00);
    }

    #[test]
    fn subnormals() {
        let tiny = 5.96e-8f32; // smallest positive f16 subnormal ≈ 5.96e-8
        let y = round_f16(tiny);
        assert!(y > 0.0 && y < 1.3e-7);
        let zero = round_f16(1e-9);
        assert_eq!(zero, 0.0);
    }

    #[test]
    fn nan_preserved() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn relative_error_bound_normals() {
        // f16 has 11 significand bits -> rel err <= 2^-11.
        let mut x = 1e-4f32;
        while x < 6e4 {
            let y = round_f16(x);
            assert!(((x - y) / x).abs() <= 1.0 / 2048.0 + 1e-9, "{x}");
            x *= 1.37;
        }
    }
}
