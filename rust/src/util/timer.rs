//! Wall-clock timing helpers used by the coordinator metrics and the
//! bench harness.

use std::time::{Duration, Instant};

/// Simple scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a duration compactly ("1.23s", "45.6ms", "789µs").
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

/// Time a closure, returning (result, duration).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(120)), "120µs");
    }

    #[test]
    fn timed_returns_value() {
        let (v, d) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
