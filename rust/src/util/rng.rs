//! Deterministic pseudo-random number generation.
//!
//! Everything in this repository that involves randomness (synthetic
//! weights, datasets, property tests, request traces) flows through
//! [`Rng`], a Xoshiro256** generator seeded via SplitMix64. No external
//! crates, fully reproducible across runs and platforms.

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG. Deterministic, fast, good statistical quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full f32 mantissa coverage.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; cheap enough).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a vector with iid normals.
    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_ms(mean, std)).collect()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn pick_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut target = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(17);
            assert!(k < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn pick_weighted_bias() {
        let mut r = Rng::new(5);
        let w = [1.0f32, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.pick_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
