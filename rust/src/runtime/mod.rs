//! PJRT runtime layer: manifest parsing ([`manifest`]) and compiled
//! graph execution ([`executor`]). The coordinator builds everything
//! above this; nothing below it knows about the paper.

pub mod executor;
pub mod manifest;

pub use executor::{literal_to_host, Executor, HostTensor, OwnedExecutor, Runtime};
pub use manifest::{Dtype, GraphSpec, InputSpec, Manifest, ModelCfg, SizeEntry};
