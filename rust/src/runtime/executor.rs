//! PJRT executor: load HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API, xla_extension 0.5.1 CPU) behind a
//! typed interface driven by the manifest's [`GraphSpec`]: inputs are
//! validated against the recorded shapes/dtypes before every call —
//! a wrong buffer order fails loudly instead of silently miscomputing.
//! (Offline builds resolve `xla` to the stub in `rust/vendor/xla`,
//! where [`Runtime::cpu`] returns a clear "PJRT unavailable" error;
//! everything above this module is runtime-agnostic.)
//!
//! aot.py lowers every graph with `return_tuple=True`, and this PJRT
//! wrapper returns the tuple as a *single* device buffer; outputs are
//! therefore downloaded and decomposed on the host after each call.
//! Large read-only inputs (base weights) are uploaded once as device
//! buffers and reused across calls — the per-step traffic is only the
//! batch plus the small LoRA/optimizer state.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::manifest::{Dtype, GraphSpec, InputSpec};

/// Typed host-side tensor handed to / received from a graph.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
            HostTensor::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(_) => Dtype::F32,
            HostTensor::I32(_) => Dtype::I32,
            HostTensor::U8(_) => Dtype::U8,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor, got {}", self.dtype()),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            HostTensor::U8(v) => Ok(v),
            _ => bail!("expected u8 tensor, got {}", self.dtype()),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor, got {}", self.dtype()),
        }
    }
}

/// Shared PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile a graph described by the manifest.
    pub fn load(&self, spec: &GraphSpec) -> Result<Executor<'_>> {
        let exe = self.compile_file(&spec.file)?;
        Ok(Executor { runtime: self, exe, spec: spec.clone() })
    }

    /// Like [`Runtime::load`], but the executor *owns* the runtime via
    /// `Arc` — for worker threads that must not borrow. The batch
    /// server used to `Box::leak` a `Runtime` per spawn to satisfy
    /// [`Executor`]'s lifetime; an [`OwnedExecutor`] drops its runtime
    /// with the worker instead of leaking one per spawn.
    pub fn load_owned(self: Arc<Self>, spec: &GraphSpec) -> Result<OwnedExecutor> {
        let exe = self.compile_file(&spec.file)?;
        Ok(OwnedExecutor { runtime: self, exe, spec: spec.clone() })
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        if !path.exists() {
            bail!(
                "HLO artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Upload a host tensor as a device buffer (for long-lived state).
    pub fn to_device(&self, t: &HostTensor, shape: &[usize]) -> Result<xla::PjRtBuffer> {
        let buf = match t {
            HostTensor::F32(v) => {
                self.client.buffer_from_host_buffer::<f32>(v, shape, None)?
            }
            HostTensor::I32(v) => {
                self.client.buffer_from_host_buffer::<i32>(v, shape, None)?
            }
            HostTensor::U8(v) => {
                self.client.buffer_from_host_buffer::<u8>(v, shape, None)?
            }
        };
        Ok(buf)
    }
}

/// Validate dtype + element count against input slot `i` of `spec`;
/// every upload path of both executor flavors funnels through here.
fn validate_slot(spec: &GraphSpec, i: usize, dtype: Dtype, len: usize) -> Result<&InputSpec> {
    let Some(s) = spec.inputs.get(i) else {
        bail!(
            "input slot {} out of range: graph {} has {} inputs",
            i,
            spec.file.display(),
            spec.inputs.len()
        );
    };
    if dtype != s.dtype {
        bail!("input {} ('{}'): dtype {} != manifest {}", i, s.name, dtype, s.dtype);
    }
    if len != s.elems() {
        bail!(
            "input {} ('{}'): {} elems != manifest shape {:?} ({})",
            i, s.name, len, s.shape, s.elems()
        );
    }
    Ok(s)
}

/// Execute a compiled graph over device buffers; download + decompose
/// the result tuple into typed host tensors (manifest-checked count).
fn execute_with(
    exe: &xla::PjRtLoadedExecutable,
    spec: &GraphSpec,
    inputs: &[&xla::PjRtBuffer],
) -> Result<Vec<HostTensor>> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "graph {} expects {} inputs, got {}",
            spec.file.display(), spec.inputs.len(), inputs.len()
        );
    }
    let mut res = exe.execute_b(inputs).context("execute_b")?;
    let replica = res.pop().context("no device results")?;
    let buf = replica.first().context("empty replica result")?;
    let mut lit = buf.to_literal_sync()?;
    let parts = lit.decompose_tuple().context("decomposing result tuple")?;
    if parts.len() != spec.n_outputs {
        bail!(
            "graph {} returned {} outputs, manifest says {}",
            spec.file.display(), parts.len(), spec.n_outputs
        );
    }
    parts.into_iter().map(literal_to_host).collect()
}

/// A compiled graph bound to its manifest contract.
pub struct Executor<'rt> {
    runtime: &'rt Runtime,
    exe: xla::PjRtLoadedExecutable,
    spec: GraphSpec,
}

impl<'rt> Executor<'rt> {
    pub fn spec(&self) -> &GraphSpec {
        &self.spec
    }

    /// Validate dtype + element count against input slot `i`.
    fn validate_input(&self, i: usize, dtype: Dtype, len: usize) -> Result<&InputSpec> {
        validate_slot(&self.spec, i, dtype, len)
    }

    /// Validate one host tensor against input slot `i`.
    fn check(&self, i: usize, t: &HostTensor) -> Result<()> {
        self.validate_input(i, t.dtype(), t.len()).map(|_| ())
    }

    /// Upload host tensors per the manifest order (with validation).
    pub fn upload_inputs(&self, inputs: &[HostTensor]) -> Result<Vec<xla::PjRtBuffer>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "graph {} expects {} inputs, got {}",
                self.spec.file.display(), self.spec.inputs.len(), inputs.len()
            );
        }
        inputs
            .iter()
            .enumerate()
            .map(|(i, t)| {
                self.check(i, t)?;
                self.runtime.to_device(t, &self.spec.inputs[i].shape)
            })
            .collect()
    }

    /// Upload a single input by slot index.
    pub fn upload_one(&self, i: usize, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.check(i, t)?;
        self.runtime.to_device(t, &self.spec.inputs[i].shape)
    }

    /// Upload an f32 slice into input slot `i` without building an
    /// owned [`HostTensor`] first — the zero-copy path the evaluator
    /// and batch server use for long-lived weights and per-batch
    /// scratch buffers.
    pub fn upload_f32(&self, i: usize, v: &[f32]) -> Result<xla::PjRtBuffer> {
        let s = self.validate_input(i, Dtype::F32, v.len())?;
        Ok(self.runtime.client.buffer_from_host_buffer::<f32>(v, &s.shape, None)?)
    }

    /// Upload an i32 slice into input slot `i` (see [`Self::upload_f32`]).
    pub fn upload_i32(&self, i: usize, v: &[i32]) -> Result<xla::PjRtBuffer> {
        let s = self.validate_input(i, Dtype::I32, v.len())?;
        Ok(self.runtime.client.buffer_from_host_buffer::<i32>(v, &s.shape, None)?)
    }

    /// Execute over device buffers; download + decompose the result
    /// tuple into typed host tensors (manifest-checked count).
    pub fn execute(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        execute_with(&self.exe, &self.spec, inputs)
    }

    /// Upload + execute host tensors.
    pub fn call(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let bufs = self.upload_inputs(inputs)?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute(&refs)
    }

    /// Upload + execute, converting every output to f32.
    pub fn call_f32(&self, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        self.call(inputs)?.into_iter().map(|t| t.into_f32()).collect()
    }
}

/// A compiled graph that owns its PJRT runtime (see
/// [`Runtime::load_owned`]). Exposes the subset of [`Executor`]'s
/// surface the serving worker needs; both flavors share the same
/// validation and execution cores, so behavior is identical.
pub struct OwnedExecutor {
    runtime: Arc<Runtime>,
    exe: xla::PjRtLoadedExecutable,
    spec: GraphSpec,
}

impl OwnedExecutor {
    pub fn spec(&self) -> &GraphSpec {
        &self.spec
    }

    /// The runtime this executor keeps alive.
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Upload an f32 slice into input slot `i` (zero-copy host side,
    /// see [`Executor::upload_f32`]).
    pub fn upload_f32(&self, i: usize, v: &[f32]) -> Result<xla::PjRtBuffer> {
        let s = validate_slot(&self.spec, i, Dtype::F32, v.len())?;
        Ok(self.runtime.client.buffer_from_host_buffer::<f32>(v, &s.shape, None)?)
    }

    /// Upload an i32 slice into input slot `i`.
    pub fn upload_i32(&self, i: usize, v: &[i32]) -> Result<xla::PjRtBuffer> {
        let s = validate_slot(&self.spec, i, Dtype::I32, v.len())?;
        Ok(self.runtime.client.buffer_from_host_buffer::<i32>(v, &s.shape, None)?)
    }

    /// Execute over device buffers (manifest-checked, as
    /// [`Executor::execute`]).
    pub fn execute(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        execute_with(&self.exe, &self.spec, inputs)
    }
}

/// Convert a downloaded literal into a typed host tensor.
pub fn literal_to_host(lit: xla::Literal) -> Result<HostTensor> {
    Ok(match lit.ty()? {
        xla::ElementType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
        xla::ElementType::S32 => HostTensor::I32(lit.to_vec::<i32>()?),
        xla::ElementType::U8 => HostTensor::U8(lit.to_vec::<u8>()?),
        other => {
            // everything else (f64 accumulators etc.) flows back as f32
            let conv = lit.convert(xla::PrimitiveType::F32)?;
            let _ = other;
            HostTensor::F32(conv.to_vec::<f32>()?)
        }
    })
}
