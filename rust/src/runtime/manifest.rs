//! artifacts/manifest.json parser.
//!
//! The manifest is the contract between `python/compile/aot.py` and the
//! Rust runtime: which HLO file implements which graph, the positional
//! input specs (name/shape/dtype) and output counts, plus each model
//! size's configuration. serde is not in the offline vendor set, so
//! this module includes a small recursive-descent JSON parser —
//! sufficient for the manifest subset (objects, arrays, strings,
//! numbers, bools) and fully unit-tested.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

// ---------------------------------------------------------------------------
// Minimal JSON value + parser
// ---------------------------------------------------------------------------
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().context("bad number")?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => out.push(c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Typed manifest
// ---------------------------------------------------------------------------

/// Element dtype of a graph input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U8,
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dtype::F32 => write!(f, "f32"),
            Dtype::I32 => write!(f, "i32"),
            Dtype::U8 => write!(f, "u8"),
        }
    }
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u8" => Ok(Dtype::U8),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

/// One positional graph input.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl InputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT graph (HLO file + I/O contract).
#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
    pub n_outputs: usize,
}

/// Model configuration as recorded by aot.py.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub rank: usize,
    pub lora_alpha: f32,
}

/// One model size: config + its graphs.
#[derive(Clone, Debug)]
pub struct SizeEntry {
    pub config: ModelCfg,
    pub graphs: BTreeMap<String, GraphSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub sizes: BTreeMap<String, SizeEntry>,
    pub kernels: BTreeMap<String, GraphSpec>,
}

fn parse_graph(dir: &Path, j: &Json) -> Result<GraphSpec> {
    let inputs = j
        .req("inputs")?
        .as_arr()?
        .iter()
        .map(|s| {
            Ok(InputSpec {
                name: s.req("name")?.as_str()?.to_string(),
                shape: s
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: Dtype::parse(s.req("dtype")?.as_str()?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(GraphSpec {
        file: dir.join(j.req("file")?.as_str()?),
        inputs,
        n_outputs: j.req("n_outputs")?.as_usize()?,
    })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut sizes = BTreeMap::new();
        for (tag, entry) in j.req("sizes")?.as_obj()? {
            let c = entry.req("config")?;
            let config = ModelCfg {
                name: c.req("name")?.as_str()?.to_string(),
                vocab: c.req("vocab")?.as_usize()?,
                d_model: c.req("d_model")?.as_usize()?,
                n_layers: c.req("n_layers")?.as_usize()?,
                n_heads: c.req("n_heads")?.as_usize()?,
                d_ff: c.req("d_ff")?.as_usize()?,
                seq: c.req("seq")?.as_usize()?,
                batch: c.req("batch")?.as_usize()?,
                rank: c.req("rank")?.as_usize()?,
                lora_alpha: c.req("lora_alpha")?.as_f64()? as f32,
            };
            let mut graphs = BTreeMap::new();
            for (gname, gj) in entry.req("graphs")?.as_obj()? {
                graphs.insert(gname.clone(), parse_graph(&dir, gj)?);
            }
            sizes.insert(tag.clone(), SizeEntry { config, graphs });
        }

        let mut kernels = BTreeMap::new();
        for (kname, kj) in j.req("kernels")?.as_obj()? {
            kernels.insert(kname.clone(), parse_graph(&dir, kj)?);
        }

        Ok(Manifest { dir, sizes, kernels })
    }

    pub fn size(&self, tag: &str) -> Result<&SizeEntry> {
        self.sizes
            .get(tag)
            .ok_or_else(|| anyhow!("size '{tag}' not in manifest (have: {:?})",
                self.sizes.keys().collect::<Vec<_>>()))
    }

    pub fn graph(&self, tag: &str, name: &str) -> Result<&GraphSpec> {
        self.size(tag)?
            .graphs
            .get(name)
            .ok_or_else(|| anyhow!("graph '{name}' missing for size '{tag}'"))
    }

    pub fn kernel(&self, name: &str) -> Result<&GraphSpec> {
        self.kernels
            .get(name)
            .ok_or_else(|| anyhow!("kernel '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.req("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].req("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn graph_spec_roundtrip() {
        let text = r#"{
            "file": "g.hlo.txt",
            "inputs": [
                {"name": "x", "shape": [2, 3], "dtype": "f32"},
                {"name": "t", "shape": [], "dtype": "i32"}
            ],
            "n_outputs": 2
        }"#;
        let g = parse_graph(Path::new("/art"), &Json::parse(text).unwrap()).unwrap();
        assert_eq!(g.file, PathBuf::from("/art/g.hlo.txt"));
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].elems(), 6);
        assert_eq!(g.inputs[1].dtype, Dtype::I32);
        assert_eq!(g.n_outputs, 2);
    }

    #[test]
    fn missing_manifest_is_error() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }
}
