//! Integration: the information-budgeted mixed-precision planner.
//!
//! Covers the subsystem's acceptance contract end to end, fully
//! offline: (1) planning a synthetic model at an average budget of
//! 3.2 code bits/weight yields a mixed-k plan that stays within
//! budget while matching or beating the uniform 3-bit ICQ baseline's
//! mean code entropy; (2) plans round-trip bit-identically through
//! `.irqc` serialize / peek / load; (3) a mixed-k `QuantizedModel`
//! dequantizes bit-identically to per-tensor uniform-k oracles; and
//! (4) version-1 (pre-planner) uniform-k checkpoints still load and
//! serve unchanged.

use irqlora::coordinator::{quantize_model, quantize_model_planned, serve_registry};
use irqlora::model::checkpoint;
use irqlora::model::weights::NamedTensors;
use irqlora::precision::{
    plan, plan_model, profile_model, synthetic_model, PlannerConfig, ProfileConfig,
};
use irqlora::quant::icq::IcqConfig;
use irqlora::quant::{Method, QuantizedTensor};
use irqlora::util::{Rng, Tensor};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("irqc_plan_test_{name}_{}", std::process::id()))
}

/// Exact (all-blocks) profile so entropy numbers match the quantized
/// artifacts bit for bit.
fn full_profile_cfg() -> ProfileConfig {
    ProfileConfig { max_blocks: None, ..ProfileConfig::default() }
}

#[test]
fn budget_3_2_yields_mixed_plan_within_budget_beating_uniform3() {
    let base = synthetic_model(2, 64, 42);
    let plan = plan_model(&base, &full_profile_cfg(), &PlannerConfig::new(3.2)).unwrap();

    // a genuinely mixed assignment
    let mut ks: Vec<u8> = plan.entries.iter().map(|e| e.k).collect();
    ks.sort_unstable();
    ks.dedup();
    assert!(ks.len() >= 2, "plan is uniform: {}", plan.render_table());
    assert!(plan.is_mixed());

    // total storage within budget — checked on the plan's exact
    // integer accounting AND on the actually-quantized artifacts
    assert!(
        plan.avg_code_bits() <= 3.2 + 1e-9,
        "plan over budget: {}",
        plan.avg_code_bits()
    );
    let qm = quantize_model_planned(&base, &plan, &IcqConfig::default()).unwrap();
    let code_bits: usize = qm.storage.iter().map(|(_, qt)| qt.len * qt.k as usize).sum();
    let params: usize = qm.storage.iter().map(|(_, qt)| qt.len).sum();
    assert_eq!(code_bits, plan.total_code_bits());
    assert_eq!(params, plan.total_params());
    assert!(code_bits as f64 <= 3.2 * params as f64 + 1e-6);

    // model mean code entropy >= the uniform 3-bit ICQ baseline's
    let uniform3 = quantize_model(&base, Method::NfIcq { k: 3 }, 0).unwrap();
    assert!(
        qm.mean_entropy() >= uniform3.mean_entropy() - 1e-9,
        "planned {:.4} < uniform-3 {:.4}\n{}",
        qm.mean_entropy(),
        uniform3.mean_entropy(),
        plan.render_table()
    );
}

#[test]
fn mixed_k_model_dequantizes_bit_identically_to_uniform_oracles() {
    let base = synthetic_model(1, 64, 7);
    let icq_cfg = IcqConfig::default();
    let plan = plan_model(&base, &full_profile_cfg(), &PlannerConfig::new(3.2)).unwrap();
    let qm = quantize_model_planned(&base, &plan, &icq_cfg).unwrap();
    assert!(plan.is_mixed());

    for (name, qt) in &qm.storage {
        let k = plan.k_for(name).unwrap();
        assert_eq!(qt.k, k, "{name}");
        // oracle: quantize THIS tensor alone, uniformly, at the same k
        let oracle = QuantizedTensor::quantize(base.get(name).unwrap(), k, 64, Some(&icq_cfg));
        assert_eq!(qt.packed, oracle.packed, "{name}: packed codes differ");
        let want = oracle.dequantize();
        let got = qm.dequantized.get(name).unwrap();
        assert_eq!(got.shape(), want.shape(), "{name}");
        for (i, (a, b)) in got.data().iter().zip(want.data()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{name}[{i}]: {a} vs {b}");
        }
    }
    // entropy bookkeeping matches the plan's prediction exactly (the
    // profile measured every block)
    for e in &plan.entries {
        let r = qm.reports.iter().find(|r| r.name == e.name).unwrap();
        assert!(
            (r.entropy - e.entropy).abs() < 1e-9,
            "{}: report {} vs plan {}",
            e.name,
            r.entropy,
            e.entropy
        );
    }
}

#[test]
fn plan_roundtrips_bit_identically_through_irqc() {
    let base = synthetic_model(1, 32, 5);
    // several budgets to vary the entry set
    for (i, budget) in [2.5f64, 3.0, 3.2, 4.5].iter().enumerate() {
        let profile = profile_model(&base, &ProfileConfig::default());
        let p = plan(&profile, &PlannerConfig::new(*budget)).unwrap();
        let mut nt = NamedTensors::new();
        nt.push("l0.wq", base.get("l0.wq").unwrap().clone());
        let path = tmp(&format!("roundtrip_{i}"));
        checkpoint::save_with_plan(&nt, &p, &path).unwrap();

        // peek (header-only) and load must both reproduce the plan
        // bit for bit
        for got in [
            checkpoint::peek_plan(&path).unwrap().unwrap(),
            checkpoint::load_with_plan(&path).unwrap().1.unwrap(),
        ] {
            assert_eq!(got.budget_bits.to_bits(), p.budget_bits.to_bits());
            assert_eq!(got.block, p.block);
            assert_eq!(got.entries.len(), p.entries.len());
            for (a, b) in p.entries.iter().zip(&got.entries) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.k, b.k);
                assert_eq!(a.n_params, b.n_params);
                assert_eq!(a.entropy.to_bits(), b.entropy.to_bits());
                assert_eq!(a.bits_per_weight.to_bits(), b.bits_per_weight.to_bits());
            }
        }
        // the tensor payload survives alongside the plan
        let (back, _) = checkpoint::load_with_plan(&path).unwrap();
        assert_eq!(back.get("l0.wq").unwrap(), nt.get("l0.wq").unwrap());
        std::fs::remove_file(path).ok();
    }
}

#[test]
fn pre_planner_uniform_v1_checkpoints_load_and_serve_unchanged() {
    // (a) plain save() still writes version-1 bytes — the exact
    // format every pre-planner checkpoint on disk uses
    let mut rng = Rng::new(3);
    let base = synthetic_model(1, 32, 9);
    let p = tmp("v1_base");
    checkpoint::save(&base, &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    assert_eq!(&bytes[..4], b"IRQC");
    assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 1);

    // (b) all readers handle it; no phantom plan appears
    let loaded = checkpoint::load(&p).unwrap();
    assert_eq!(loaded.names(), base.names());
    for (name, t) in base.iter() {
        assert_eq!(loaded.get(name).unwrap(), t, "{name}");
    }
    let (_, plan) = checkpoint::load_with_plan(&p).unwrap();
    assert!(plan.is_none());
    assert!(checkpoint::peek_plan(&p).unwrap().is_none());
    assert!(!checkpoint::peek_entries(&p).unwrap().is_empty());
    std::fs::remove_file(&p).ok();

    // (c) the uniform-k pipeline over a v1-loaded base serves through
    // the registry exactly as before, including a v1 adapter file
    let qm = quantize_model(&loaded, Method::NfIcq { k: 4 }, 0).unwrap();
    let reg = serve_registry(&qm, (1.0, 1.0));
    let mut adapter = NamedTensors::new();
    adapter.push("l0.wq.lora_a", Tensor::new(&[64, 4], rng.normal_vec(256, 0.0, 0.3)));
    adapter.push("l0.wq.lora_b", Tensor::new(&[4, 64], rng.normal_vec(256, 0.0, 0.3)));
    adapter.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
    let ap = tmp("v1_adapter");
    checkpoint::save(&adapter, &ap).unwrap();
    reg.register_file("tenant", &ap).unwrap();
    let merged = reg.merged("tenant").unwrap();
    assert!(merged.get("betas").unwrap().data().iter().all(|&x| x == 0.0));
    std::fs::remove_file(&ap).ok();
}

#[test]
fn corrupt_plan_blob_in_checkpoint_is_an_error_not_a_panic() {
    let base = synthetic_model(1, 32, 21);
    let plan = plan_model(&base, &ProfileConfig::default(), &PlannerConfig::new(3.2)).unwrap();
    let mut nt = NamedTensors::new();
    nt.push("w", Tensor::full(&[8], 1.0));
    let p = tmp("corrupt_plan");
    checkpoint::save_with_plan(&nt, &plan, &p).unwrap();
    let good = std::fs::read(&p).unwrap();
    // flip one byte at every offset inside the plan section
    let plan_len =
        u32::from_le_bytes([good[12], good[13], good[14], good[15]]) as usize;
    for off in (16..16 + plan_len).step_by(7) {
        let mut bad = good.clone();
        bad[off] ^= 0x5a;
        std::fs::write(&p, &bad).unwrap();
        // any outcome but a panic is fine for peek; the checksummed
        // full load must reject the file whenever the plan parses at
        // all (fnv covers the plan bytes)
        let _ = checkpoint::peek_plan(&p);
        assert!(checkpoint::load_with_plan(&p).is_err(), "offset {off} accepted");
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn planned_avg_bits_accounts_constants_on_top_of_code_bits() {
    // budget governs code bits; full storage = code + ~0.25 b/w of
    // double-quantized s/τ constants at block 64
    let base = synthetic_model(1, 32, 33);
    let plan = plan_model(&base, &full_profile_cfg(), &PlannerConfig::new(3.0)).unwrap();
    let overhead = plan.avg_bits() - plan.avg_code_bits();
    assert!(
        (0.2..0.3).contains(&overhead),
        "constants overhead {overhead} outside the expected band"
    );
    let qm = quantize_model_planned(&base, &plan, &IcqConfig::default()).unwrap();
    let storage_bits: usize = qm.storage.iter().map(|(_, qt)| qt.storage_bits()).sum();
    assert_eq!(storage_bits, plan.total_storage_bits());
}
