//! Disabled telemetry must be zero-cost — not "cheap", ZERO:
//!
//! - resolving a handle from a disabled registry returns before any
//!   key string is formatted (no allocation);
//! - every recording op on a no-op handle is a single branch on a
//!   `None` (no allocation, no clock read);
//! - a disabled registry never creates its JSONL file, even when
//!   `with_jsonl` was called.
//!
//! Enforced with a counting `#[global_allocator]`: the steady-state
//! window (handle resolution + 10k recording ops + a flush) must see
//! exactly zero heap allocations. This file deliberately holds ONE
//! `#[test]` — a second test running on a sibling thread would
//! allocate inside the window and turn the assert flaky.

use irqlora::telemetry::Registry;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// `System` with an allocation odometer. Frees are not counted — the
/// contract under test is "allocates nothing", so only acquisitions
/// matter.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn disabled_telemetry_allocates_nothing_and_writes_nothing() {
    // Construction may allocate (map, mutexes) — only the steady
    // state after construction has the zero-allocation contract.
    let sink = std::env::temp_dir()
        .join(format!("irqlora_disabled_telem_{}.jsonl", std::process::id()));
    std::fs::remove_file(&sink).ok();
    let reg = Registry::disabled().with_jsonl(&sink);
    assert!(!reg.is_enabled());
    assert!(!reg.has_jsonl(), "a disabled registry must drop the JSONL attachment");

    let before = ALLOCS.load(Ordering::SeqCst);

    // Handle resolution: the disabled check precedes key formatting,
    // so even label-carrying lookups allocate nothing.
    let c = reg.counter("serve.requests", &[("adapter", "tenant0")]);
    let g = reg.gauge("pool.parked_peak", &[]);
    let t = reg.timer("hal.forward_time", &[("backend", "reference")]);

    for i in 0..10_000u64 {
        c.inc();
        c.add(i);
        g.set(i);
        g.set_max(i);
        // guard drop records nothing and never reads the clock
        let _guard = t.start();
    }
    // flush on a registry without a sink is Ok(()) and touches no file
    reg.flush_jsonl().expect("disabled flush must be a no-op Ok(())");

    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "disabled telemetry allocated {} times in the steady state",
        after - before
    );

    // nothing was recorded anywhere...
    assert_eq!(c.get(), 0);
    assert_eq!(g.get(), 0);
    assert_eq!(t.samples(), 0);
    assert_eq!(t.total().as_nanos(), 0);
    assert!(reg.snapshot().is_empty(), "disabled registry grew slots");
    // ...and no JSONL file ever appeared
    assert!(
        !sink.exists(),
        "disabled registry created {sink:?} — disabled telemetry must never touch disk"
    );
}
