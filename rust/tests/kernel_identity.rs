//! Packed-kernel bit-identity battery (verify.sh gate, exit 17).
//!
//! The `kernels` layer's contract is that `gemm_packed` — computing
//! y = W_q·x straight from packed NF-k storage — lands on the EXACT
//! bits of the two-step oracle "dequantize the tensor, then run the
//! serial `gemm_f32_reference` matmul", for every bit-width, every
//! ragged shape, partial and all-zero blocks, and mixed-k planned
//! models. verify.sh runs this battery under
//! `IRQLORA_SERVE_BACKEND=native` so the packed path is exercised in
//! the same process configuration the serving smoke uses.
//!
//! The sweeps are property-style: shapes, block sizes and inputs are
//! drawn from the in-tree seeded [`Rng`] (the vendored dependency set
//! has no proptest), so every run covers the same reproducible case
//! matrix and any failure prints the exact (k, shape, block, icq)
//! coordinates that produced it.

use irqlora::coordinator::quantize::quantize_model_planned;
use irqlora::kernels::{
    gemm_f32, gemm_f32_reference, gemm_packed, gemm_packed_hist, gemm_packed_hist_reference,
    gemm_packed_into, gemm_packed_reference, PackedGemmScratch,
};
use irqlora::model::weights::NamedTensors;
use irqlora::precision::{PlanEntry, PrecisionPlan};
use irqlora::quant::{icq::IcqConfig, QuantizedTensor};
use irqlora::{Rng, Tensor};

const SWEEP_K: [u8; 4] = [2, 3, 4, 8];

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx} row {i}: {a} vs {b}");
    }
}

/// The two-step oracle the packed kernel must reproduce bit-for-bit.
fn dequant_then_matmul(qt: &QuantizedTensor, x: &[f32]) -> Vec<f32> {
    let rows = qt.shape[0];
    let cols: usize = qt.shape[1..].iter().product();
    gemm_f32_reference(qt.dequantize().data(), x, rows, cols, 1)
}

fn sweep_case(rng: &mut Rng, rows: usize, cols: usize, k: u8, block: usize, icq: Option<&IcqConfig>) {
    let ctx = format!("rows={rows} cols={cols} k={k} block={block} icq={}", icq.is_some());
    let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.8));
    let qt = QuantizedTensor::quantize(&w, k, block, icq);
    let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
    let want = dequant_then_matmul(&qt, &x);
    assert_bits_eq(&gemm_packed(&qt, &x), &want, &ctx);
    assert_bits_eq(&gemm_packed_reference(&qt, &x), &want, &ctx);
    // the dense blocked kernel agrees with its own serial twin too
    let dq = qt.dequantize();
    assert_bits_eq(
        &gemm_f32(dq.data(), &x, rows, cols, 1),
        &want,
        &format!("{ctx} [dense]"),
    );
}

/// Ragged shapes × every supported k × vanilla/ICQ: the headline
/// bit-identity sweep.
#[test]
fn packed_gemm_bit_identical_to_dequant_oracle_across_shapes_and_k() {
    let mut rng = Rng::new(0x4b45524e);
    let icq = IcqConfig::default();
    // primes, singletons, and >serial-threshold sizes all included
    let shapes: [(usize, usize); 6] = [(1, 1), (7, 13), (16, 64), (33, 1), (5, 129), (96, 97)];
    for k in SWEEP_K {
        for (rows, cols) in shapes {
            sweep_case(&mut rng, rows, cols, k, 64, None);
            sweep_case(&mut rng, rows, cols, k, 64, Some(&icq));
        }
    }
}

/// Blocks that end mid-row, rows that end mid-block, and block sizes
/// where `block·k` is not a whole number of bytes (the dequantizer's
/// serial-fallback geometry) — the packed walk must not lose or
/// duplicate a single code.
#[test]
fn packed_gemm_handles_partial_blocks_and_unaligned_geometries() {
    let mut rng = Rng::new(0x504b4731);
    let icq = IcqConfig::default();
    for k in SWEEP_K {
        for (rows, cols, block) in [
            (4usize, 10usize, 3usize), // block*k % 8 != 0 for k=2,3,4,8? (3k odd bytes)
            (5, 9, 7),
            (3, 17, 16),
            (9, 31, 10),
            (2, 5, 64), // one partial block spanning the whole tensor
        ] {
            sweep_case(&mut rng, rows, cols, k, block, None);
            sweep_case(&mut rng, rows, cols, k, block, Some(&icq));
        }
    }
}

/// All-zero tensors quantize to zero-scale blocks; the packed kernel
/// must reproduce the oracle's bits there too (including signed zeros).
#[test]
fn packed_gemm_zero_blocks_match_oracle() {
    let mut rng = Rng::new(0x5a45524f);
    for k in SWEEP_K {
        let (rows, cols) = (6usize, 32usize);
        let mut data = vec![0f32; rows * cols];
        // half the blocks zero, half live
        for (i, v) in data.iter_mut().enumerate() {
            if (i / 16) % 2 == 0 {
                *v = rng.normal();
            }
        }
        let w = Tensor::new(&[rows, cols], data);
        let qt = QuantizedTensor::quantize(&w, k, 16, None);
        let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
        let want = dequant_then_matmul(&qt, &x);
        assert_bits_eq(&gemm_packed(&qt, &x), &want, &format!("k={k} zero-blocks"));
    }
}

/// Mixed-k planned models: every stored tensor keeps its own k, and
/// both the raw kernel and the `QuantizedModel::packed_matvec` wrapper
/// must match the dense oracle per tensor.
#[test]
fn packed_gemm_bit_identical_on_mixed_k_planned_models() {
    let mut rng = Rng::new(0x4d495845);
    let mut m = NamedTensors::new();
    m.push("l0.wq", Tensor::new(&[24, 48], rng.normal_vec(24 * 48, 0.0, 0.7)));
    m.push("l0.w2", Tensor::new(&[40, 24], rng.normal_vec(40 * 24, 0.0, 0.7)));
    m.push("l1.wq", Tensor::new(&[24, 48], rng.normal_vec(24 * 48, 0.0, 0.7)));
    m.push("embed", Tensor::new(&[10, 24], rng.normal_vec(240, 0.0, 0.7)));
    let entries = [("l0.wq", 2u8), ("l0.w2", 4), ("l1.wq", 8)]
        .into_iter()
        .map(|(name, k)| PlanEntry {
            name: name.into(),
            k,
            n_params: m.get(name).unwrap().len(),
            entropy: 0.0,
            bits_per_weight: 0.0,
        })
        .collect();
    let plan = PrecisionPlan { budget_bits: 4.0, block: 24, entries };
    let qm = quantize_model_planned(&m, &plan, &IcqConfig::default()).unwrap();
    assert_eq!(qm.storage.len(), 3);

    let mut y = Vec::new();
    let mut scratch = PackedGemmScratch::new();
    for (name, qt) in &qm.storage {
        let cols: usize = qt.shape[1..].iter().product();
        let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
        let want = dequant_then_matmul(qt, &x);
        assert_bits_eq(&gemm_packed(qt, &x), &want, name);
        qm.packed_matvec(name, &x, &mut y, &mut scratch).unwrap();
        assert_bits_eq(&y, &want, &format!("{name} [packed_matvec]"));
    }
}

/// The steady-state `_into` API reuses caller buffers across calls of
/// different shapes without carrying stale state between them.
#[test]
fn packed_gemm_into_reuses_buffers_across_tensors() {
    let mut rng = Rng::new(0x494e544f);
    let mut y = vec![f32::NAN; 999]; // stale garbage must be cleared
    let mut scratch = PackedGemmScratch::new();
    for (rows, cols, k) in [(8usize, 24usize, 4u8), (3, 65, 2), (17, 8, 8)] {
        let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.8));
        let qt = QuantizedTensor::quantize(&w, k, 16, None);
        let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
        gemm_packed_into(&qt, &x, &mut y, &mut scratch);
        assert_bits_eq(&y, &dequant_then_matmul(&qt, &x), &format!("k={k}"));
    }
}

/// The histogram variant is its own twin pair: parallel and serial
/// must be bit-identical to each other, and within tolerance of the
/// exact path (it reassociates the k-reduction by code, so exactness
/// is not claimed — see `kernels` module docs).
#[test]
fn hist_variant_twins_agree_and_track_the_exact_path() {
    let mut rng = Rng::new(0x48495354);
    for k in SWEEP_K {
        let (rows, cols) = (11usize, 53usize);
        let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.8));
        let qt = QuantizedTensor::quantize(&w, k, 16, Some(&IcqConfig::default()));
        let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
        let fast = gemm_packed_hist(&qt, &x);
        assert_bits_eq(&fast, &gemm_packed_hist_reference(&qt, &x), &format!("k={k} hist"));
        for (i, (h, e)) in fast.iter().zip(gemm_packed(&qt, &x)).enumerate() {
            assert!(
                (h - e).abs() <= 1e-4 * (1.0 + e.abs()),
                "k={k} row {i}: hist {h} vs exact {e}"
            );
        }
    }
}
