//! Cross-backend capability matrix for the serving HAL.
//!
//! The backend registry (`irqlora::hal`) is the single source of truth
//! for what each backend can do; this battery derives its coverage
//! from the manifests instead of hard-coding backend names:
//!
//! - **capability-driven fan-out**: every registered backend whose
//!   manifest claims the battery's required capabilities (serve shape,
//!   fused multi-adapter forward, availability gate) runs the full
//!   pooled contention battery — today that is `reference` and
//!   `native`; a future backend joins the matrix just by registering;
//! - **cross-backend bit-identity**: every pooled reply from every
//!   capable backend is compared bit-for-bit against ONE serial
//!   single-worker `ReferenceBackend` oracle, so two backends cannot
//!   drift from each other without failing here;
//! - **typed rejection**: malformed or contradictory manifests are
//!   refused at registration, and unsupported (manifest, request)
//!   combinations are refused at resolve time, each with the matching
//!   [`HalError`] variant — never a mid-drain runtime surprise.

use std::sync::Arc;
use std::time::Duration;

use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
use irqlora::coordinator::pool::{PoolConfig, ServerPool};
use irqlora::coordinator::{synthetic_serve_registry, BatchServer, ServerConfig};
use irqlora::data::PAD;
use irqlora::hal::{
    BackendEntry, BackendManifest, BackendRegistry, BackendRequest, CacheSemantics, HalError,
    QuantFamily,
};

const BATCH: usize = 8;
const SEQ: usize = 32;
const VOCAB: usize = 64;
const TENANTS: usize = 6;
const WORKERS: usize = 4;
/// Fixture seed: the oracle and every backend's pool rebuild the same
/// registry from it, so merged adapter weights are identical inputs.
const FIXTURE_SEED: u64 = 7;

/// The battery's capability requirements, as a typed request: the
/// serve shape plus a native fused multi-adapter forward (the pool
/// drains through `forward_fused`, so a scatter-only backend would
/// measure the default path twice).
fn battery_request() -> BackendRequest {
    let mut req = BackendRequest::new(BATCH, SEQ, VOCAB);
    req.workers = WORKERS;
    req.require_fused = true;
    req
}

/// Every registered backend whose manifest satisfies the battery
/// request AND whose gate reports it available in this environment.
fn capable_backends(req: &BackendRequest) -> Vec<String> {
    let hal = BackendRegistry::builtin();
    hal.names().into_iter().filter(|n| hal.resolve(n, req).is_ok()).collect()
}

/// Deterministic mixed-tenant request stream shared by the oracle and
/// every backend under test.
fn stream() -> Vec<(String, Vec<i32>)> {
    (0..64)
        .map(|i| {
            let tenant = format!("tenant{}", i % TENANTS);
            let len = 1 + (i * 7) % SEQ;
            let prompt: Vec<i32> = (0..len)
                .map(|t| ((i * 13 + t * 5) % (VOCAB - 1)) as i32 + 1)
                .collect();
            (tenant, prompt)
        })
        .collect()
}

/// Serial single-worker reference oracle: each (tenant, prompt) served
/// alone, in order, on the per-group serial path.
fn oracle_logits(stream: &[(String, Vec<i32>)]) -> Vec<Vec<f32>> {
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let reg = registry.clone();
    let oracle = BatchServer::spawn_with(
        ServerConfig::new(Duration::from_millis(1)).serial(),
        registry,
        move || {
            Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();
    let expected = stream
        .iter()
        .map(|(t, p)| oracle.query(t, p.clone()).unwrap().logits)
        .collect();
    oracle.shutdown();
    expected
}

/// The matrix itself: every capable backend serves the same contended
/// mixed-tenant stream through a 4-worker pool built by the HAL
/// factory, and every reply must be bit-identical to the serial
/// reference oracle. The capable set must contain both in-tree CPU
/// backends — if `native` ever stops claiming (or supporting) the
/// battery capabilities, this fails loudly instead of shrinking
/// coverage to reference-only.
#[test]
fn every_capable_backend_matches_the_serial_reference_oracle() {
    let req = battery_request();
    let capable = capable_backends(&req);
    assert!(
        capable.iter().any(|n| n == "reference"),
        "reference missing from capable set {capable:?}"
    );
    assert!(
        capable.iter().any(|n| n == "native"),
        "native missing from capable set {capable:?}"
    );

    let stream = stream();
    let expected = oracle_logits(&stream);

    let hal = BackendRegistry::builtin();
    for name in &capable {
        let name = name.as_str();
        let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
        let factory = hal
            .pool_factory(name, &req, registry.base().clone(), "matrix")
            .unwrap_or_else(|e| panic!("backend '{name}': {e}"));
        let pool = ServerPool::spawn_with(
            PoolConfig::new(WORKERS, Duration::from_millis(2)),
            registry,
            factory,
        )
        .unwrap();

        const SUBMITTERS: usize = 4;
        std::thread::scope(|scope| {
            for t in 0..SUBMITTERS {
                let pool = &pool;
                let stream = &stream;
                let expected = &expected;
                scope.spawn(move || {
                    let mut inflight: Vec<(usize, irqlora::coordinator::Pending)> = Vec::new();
                    let mut check = |inflight: &mut Vec<(usize, irqlora::coordinator::Pending)>| {
                        for (j, h) in inflight.drain(..) {
                            let r = h.wait().unwrap();
                            assert_eq!(
                                r.logits, expected[j],
                                "backend '{name}' request {j} diverged from the serial \
                                 reference oracle"
                            );
                        }
                    };
                    for k in 0..stream.len() {
                        let i = (k + t * 11) % stream.len();
                        let (tenant, prompt) = &stream[i];
                        inflight.push((i, pool.submit_async(tenant, prompt.clone()).unwrap()));
                        if inflight.len() >= 8 {
                            check(&mut inflight);
                        }
                    }
                    check(&mut inflight);
                });
            }
        });

        let s = pool.stats();
        assert_eq!(s.requests, SUBMITTERS * stream.len(), "backend '{name}': {s:?}");
        assert_eq!(s.fused_batches, s.batches, "backend '{name}' fell off the fused path: {s:?}");
        pool.shutdown();
    }
}

/// Streamed decode across the matrix: every backend whose manifest
/// claims `streaming_decode` serves multi-step streams through a
/// pooled continuous-batching worker, and every per-step logit row
/// must be bit-identical to the serial reference oracle's one-shot
/// answer for the greedy-extended prefix at that step. Both in-tree
/// CPU backends must claim the capability.
#[test]
fn streamed_decode_matches_the_serial_oracle_across_backends() {
    let mut req = battery_request();
    req.require_streaming = true;
    let capable = capable_backends(&req);
    assert!(
        capable.iter().any(|n| n == "reference"),
        "reference missing from streaming-capable set {capable:?}"
    );
    assert!(
        capable.iter().any(|n| n == "native"),
        "native missing from streaming-capable set {capable:?}"
    );

    let oracle_registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let oracle_reg = oracle_registry.clone();
    let oracle = BatchServer::spawn_with(
        ServerConfig::new(Duration::from_millis(1)).serial(),
        oracle_registry,
        move || {
            Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, oracle_reg.base()))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();

    let hal = BackendRegistry::builtin();
    for name in &capable {
        let name = name.as_str();
        let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
        let factory = hal
            .pool_factory(name, &req, registry.base().clone(), "matrix-stream")
            .unwrap_or_else(|e| panic!("backend '{name}': {e}"));
        let pool = ServerPool::spawn_with(
            PoolConfig::new(2, Duration::from_millis(2)),
            registry,
            factory,
        )
        .unwrap();

        let cases = [(0usize, 4usize), (1, 3), (3, 2)];
        for (tn, steps) in cases {
            let tenant = format!("tenant{tn}");
            let prompt: Vec<i32> = vec![1, 2 + tn as i32, 3];
            let mut prefix = prompt.clone();
            let mut delivered = 0usize;
            for (j, r) in pool.submit_stream(&tenant, prompt, steps).unwrap().enumerate() {
                let r = r.unwrap_or_else(|e| panic!("backend '{name}' step {}: {e}", j + 1));
                assert_eq!(r.step, j + 1, "backend '{name}'");
                assert_eq!(r.last, j + 1 == steps, "backend '{name}'");
                let want = oracle.query(&tenant, prefix.clone()).unwrap().logits;
                assert_eq!(r.logits.len(), want.len(), "backend '{name}'");
                for (i, (a, b)) in r.logits.iter().zip(want.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "backend '{name}' tenant '{tenant}' step {} logit {i} diverged \
                         from the serial oracle",
                        j + 1
                    );
                }
                prefix.push(irqlora::coordinator::greedy_next_token(&r.logits));
                delivered += 1;
            }
            assert_eq!(delivered, steps, "backend '{name}' tenant '{tenant}'");
        }

        let s = pool.stats();
        assert_eq!(s.stream_requests, cases.len(), "backend '{name}': {s:?}");
        assert_eq!(
            s.steps,
            cases.iter().map(|(_, n)| *n).sum::<usize>(),
            "backend '{name}': {s:?}"
        );
        pool.shutdown();
    }
    oracle.shutdown();
}

/// Backend-level spot check below the pool machinery: one padded batch
/// (real token rows + PAD tail rows) through `forward` on every
/// capable backend's worker 0, bit-compared against the reference
/// worker and against each other, with identical upload-cache
/// accounting (one miss, then one hit, for the same generation).
#[test]
fn single_forward_and_cache_accounting_agree_across_backends() {
    let req = battery_request();
    let hal = BackendRegistry::builtin();
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let (generation, weights) = registry.merged_tagged("tenant0").unwrap();

    let mut tokens = vec![PAD; BATCH * SEQ];
    for b in 0..BATCH - 2 {
        // ragged real rows; the last two rows stay all-PAD
        for t in 0..(3 + 5 * b).min(SEQ) {
            tokens[b * SEQ + t] = ((b * 17 + t * 3) % (VOCAB - 1)) as i32 + 1;
        }
    }

    let mut want: Option<(String, Vec<f32>)> = None;
    for name in capable_backends(&req) {
        let factory = hal
            .pool_factory(&name, &req, registry.base().clone(), "matrix")
            .unwrap_or_else(|e| panic!("backend '{name}': {e}"));
        let mut backend = factory(0).unwrap();
        assert_eq!(backend.shape(), (BATCH, SEQ, VOCAB), "backend '{name}'");
        let first = backend.forward("tenant0", generation, &weights, &tokens).unwrap();
        let again = backend.forward("tenant0", generation, &weights, &tokens).unwrap();
        assert_eq!(first, again, "backend '{name}' is not deterministic");
        let stats = backend.upload_stats();
        assert_eq!(
            (stats.misses, stats.hits),
            (1, 1),
            "backend '{name}' adapter-cache accounting drifted"
        );
        match &want {
            None => want = Some((name, first)),
            Some((base_name, base)) => {
                assert_eq!(first.len(), base.len(), "'{name}' vs '{base_name}'");
                for (i, (a, b)) in first.iter().zip(base.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "logit {i}: backend '{name}' != backend '{base_name}'"
                    );
                }
            }
        }
    }
    assert!(want.is_some(), "no capable backend ran");
}

/// A well-formed manifest for the rejection tests, with a factory that
/// would actually work if the entry were ever resolved.
fn dummy_entry(name: &str) -> BackendEntry {
    BackendEntry {
        manifest: BackendManifest {
            name: name.to_string(),
            quant_families: vec![QuantFamily::NormalFloat],
            bit_widths: vec![4],
            max_batch: 4,
            max_seq: 8,
            max_vocab: 16,
            fused_multi_adapter: false,
            streaming_decode: false,
            packed_gemm: false,
            cache: CacheSemantics::None,
            approx_memory_bytes: 1024,
        },
        implements_fused: false,
        implements_step: false,
        implements_packed_gemm: false,
        gate: None,
        factory: Arc::new(|ctx| {
            Ok(Box::new(ReferenceBackend::new(
                ctx.request.batch,
                ctx.request.seq,
                ctx.request.vocab,
                &ctx.base,
            )) as Box<dyn ServeBackend>)
        }),
    }
}

/// Malformed and contradictory manifests must be refused AT
/// REGISTRATION with the typed `InvalidManifest` / `DuplicateBackend`
/// errors — the registry never holds an entry it could not construct
/// a valid backend from.
#[test]
fn registration_refuses_malformed_and_contradictory_manifests() {
    let mut reg = BackendRegistry::new();

    let mut e = dummy_entry("bad-k");
    e.manifest.bit_widths = vec![4, 9];
    match reg.register(e) {
        Err(HalError::InvalidManifest { name, reason }) => {
            assert_eq!(name, "bad-k");
            assert!(reason.contains("k=9"), "{reason}");
        }
        other => panic!("k=9 accepted: {other:?}"),
    }

    let mut e = dummy_entry("no-batch");
    e.manifest.max_batch = 0;
    assert!(matches!(reg.register(e), Err(HalError::InvalidManifest { .. })));

    let mut e = dummy_entry("no-family");
    e.manifest.quant_families.clear();
    assert!(matches!(reg.register(e), Err(HalError::InvalidManifest { .. })));

    // contradictory: the manifest advertises a single-launch fused
    // forward the implementation does not provide
    let mut e = dummy_entry("fused-liar");
    e.manifest.fused_multi_adapter = true;
    match reg.register(e) {
        Err(HalError::InvalidManifest { name, reason }) => {
            assert_eq!(name, "fused-liar");
            assert!(reason.contains("fused"), "{reason}");
        }
        other => panic!("fused-without-implementation accepted: {other:?}"),
    }

    // contradictory: the manifest advertises a single-position decode
    // step the implementation does not provide
    let mut e = dummy_entry("stream-liar");
    e.manifest.streaming_decode = true;
    match reg.register(e) {
        Err(HalError::InvalidManifest { name, reason }) => {
            assert_eq!(name, "stream-liar");
            assert!(reason.contains("streaming"), "{reason}");
        }
        other => panic!("streaming-without-implementation accepted: {other:?}"),
    }

    // contradictory: the manifest claims packed-domain GEMM consumption
    // of quantized storage, but the implementation only dequantizes
    let mut e = dummy_entry("packed-liar");
    e.manifest.packed_gemm = true;
    match reg.register(e) {
        Err(HalError::InvalidManifest { name, reason }) => {
            assert_eq!(name, "packed-liar");
            assert!(reason.contains("packed"), "{reason}");
        }
        other => panic!("packed-gemm-without-implementation accepted: {other:?}"),
    }

    reg.register(dummy_entry("dup")).unwrap();
    assert!(matches!(
        reg.register(dummy_entry("dup")),
        Err(HalError::DuplicateBackend { .. })
    ));

    // the failed registrations left no residue
    assert_eq!(reg.names(), vec!["dup".to_string()]);
}

/// Unsupported (manifest, request) combinations must be refused at
/// RESOLVE time — before any worker spawns — with the typed
/// `Unknown` / `Unsupported` variants, and the builtin `pjrt` entry's
/// availability gate must report `Unavailable` when no compiled
/// artifacts exist.
#[test]
fn resolve_refuses_unsupported_combinations_with_typed_errors() {
    let hal = BackendRegistry::builtin();

    match hal.resolve("warp-drive", &BackendRequest::new(1, 1, 1)) {
        Err(HalError::UnknownBackend { name, available }) => {
            assert_eq!(name, "warp-drive");
            assert!(available.iter().any(|n| n == "reference"), "{available:?}");
            assert!(available.iter().any(|n| n == "native"), "{available:?}");
        }
        other => panic!("unknown backend resolved: {other:?}"),
    }

    // shape beyond the reference manifest's max_batch
    let big = BackendRequest::new(100_000, SEQ, VOCAB);
    match hal.resolve("reference", &big) {
        Err(HalError::Unsupported { backend, reason }) => {
            assert_eq!(backend, "reference");
            assert!(reason.contains("batch"), "{reason}");
        }
        other => panic!("oversized batch resolved: {other:?}"),
    }

    // a fused requirement against a manifest that only scatters
    let mut reg = BackendRegistry::new();
    reg.register(dummy_entry("scatter-only")).unwrap();
    let mut req = BackendRequest::new(4, 8, 16);
    req.require_fused = true;
    assert!(matches!(
        reg.resolve("scatter-only", &req),
        Err(HalError::Unsupported { .. })
    ));
    // a streaming requirement against a manifest with no decode step
    let mut req = BackendRequest::new(4, 8, 16);
    req.require_streaming = true;
    match reg.resolve("scatter-only", &req) {
        Err(HalError::Unsupported { reason, .. }) => {
            assert!(reason.contains("streaming"), "{reason}")
        }
        other => panic!("streaming resolved against a sliced manifest: {other:?}"),
    }
    // a packed-domain GEMM requirement against a dequant-path manifest
    // — and the builtin `native` entry must satisfy the same demand
    let mut req = BackendRequest::new(4, 8, 16);
    req.require_packed_gemm = true;
    match reg.resolve("scatter-only", &req) {
        Err(HalError::Unsupported { reason, .. }) => {
            assert!(reason.contains("packed"), "{reason}")
        }
        other => panic!("packed GEMM resolved against a dequant manifest: {other:?}"),
    }
    let mut req = BackendRequest::new(4, 8, 16);
    req.require_packed_gemm = true;
    assert!(hal.resolve("native", &req).is_ok(), "native must offer packed_gemm");
    // a bit-width the manifest does not claim
    let mut req = BackendRequest::new(4, 8, 16);
    req.bit_widths = vec![2];
    match reg.resolve("scatter-only", &req) {
        Err(HalError::Unsupported { reason, .. }) => {
            assert!(reason.contains("k=2"), "{reason}")
        }
        other => panic!("unclaimed bit-width resolved: {other:?}"),
    }

    // pjrt stays registered (its restore is a ROADMAP carry-over) but
    // gates itself off until `make artifacts` has produced a manifest
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        match hal.resolve("pjrt", &BackendRequest::new(1, 1, 1)) {
            Err(HalError::Unavailable { name, reason }) => {
                assert_eq!(name, "pjrt");
                assert!(reason.contains("artifacts"), "{reason}");
            }
            other => panic!("gated pjrt resolved without artifacts: {other:?}"),
        }
    }
}
