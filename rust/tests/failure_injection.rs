//! Failure injection: the coordinator must fail loudly and precisely,
//! never silently miscompute — missing artifacts, wrong shapes, wrong
//! dtypes, corrupt checkpoints, oversized requests, and a serving
//! backend that panics mid-pool (the blast radius must stop at its
//! worker).

use irqlora::model::{checkpoint, weights::NamedTensors};
use irqlora::runtime::{Dtype, GraphSpec, HostTensor, InputSpec, Manifest, Runtime};
use irqlora::util::Tensor;

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

#[test]
fn missing_artifact_dir_is_clear_error() {
    let err = Manifest::load("/tmp/definitely-not-artifacts-xyz").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "{msg}");
}

#[test]
fn missing_hlo_file_mentions_make_artifacts() {
    let Some(_) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let spec = GraphSpec {
        file: "artifacts/no_such_graph.hlo.txt".into(),
        inputs: vec![],
        n_outputs: 1,
    };
    let err = match rt.load(&spec) {
        Err(e) => e,
        Ok(_) => panic!("loading a missing artifact should fail"),
    };
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn wrong_input_count_rejected() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();
    let err = exe.call(&[HostTensor::F32(vec![0.0; 64])]).unwrap_err();
    assert!(format!("{err:#}").contains("expects 2 inputs"));
}

#[test]
fn wrong_shape_rejected_with_name() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();
    let err = exe
        .call(&[
            HostTensor::F32(vec![0.0; 63]), // should be 64
            HostTensor::F32(vec![0.0; 201]),
        ])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("block") && msg.contains("63"), "{msg}");
}

#[test]
fn wrong_dtype_rejected() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();
    let err = exe
        .call(&[
            HostTensor::I32(vec![0; 64]), // f32 expected
            HostTensor::F32(vec![0.0; 201]),
        ])
        .unwrap_err();
    assert!(format!("{err:#}").contains("dtype"));
}

#[test]
fn corrupt_hlo_text_rejected() {
    let Some(_) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let path = std::env::temp_dir().join(format!("bad_{}.hlo.txt", std::process::id()));
    std::fs::write(&path, "HloModule garbage\nENTRY { this is not hlo }").unwrap();
    let spec = GraphSpec {
        file: path.clone(),
        inputs: vec![InputSpec { name: "x".into(), shape: vec![1], dtype: Dtype::F32 }],
        n_outputs: 1,
    };
    assert!(rt.load(&spec).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn truncated_checkpoint_detected() {
    let mut nt = NamedTensors::new();
    nt.push("w", Tensor::full(&[256], 1.5));
    let path = std::env::temp_dir().join(format!("trunc_{}.irqc", std::process::id()));
    checkpoint::save(&nt, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
    assert!(checkpoint::load(&path).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn server_rejects_oversized_prompt_without_crashing() {
    let Some(m) = manifest() else { return };
    use irqlora::coordinator::{AdapterRegistry, BatchServer, ServerConfig};
    use irqlora::model::weights::{init_base, init_lora};
    use irqlora::util::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    let tag = "xs";
    let size = m.size(tag).unwrap().clone();
    let spec = m.graph(tag, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(1);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = m.graph(tag, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora = init_lora(&tspec.inputs[nb..nb + nl], size.config.rank, &mut rng);

    let registry = Arc::new(AdapterRegistry::new(base, (0.0, 0.0)));
    registry.register("default", lora).unwrap();
    let server = BatchServer::spawn(
        m,
        tag,
        ServerConfig::new(Duration::from_millis(1)),
        registry,
    )
    .unwrap();

    // oversized prompt -> rejected at submit, before any batch slot
    let err = server.query("default", vec![1; size.config.seq + 5]).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"));
    // empty prompt -> rejected at submit
    assert!(server.query("default", vec![]).is_err());
    // unknown adapter -> rejected at submit
    assert!(server.query("ghost", vec![1, 2, 3]).is_err());
    assert_eq!(server.stats().rejected, 3);
    // server still healthy afterwards
    let ok = server.query("default", vec![1, 8, 70, 70, 4, 3]).unwrap();
    assert_eq!(ok.logits.len(), size.config.vocab);
    server.shutdown();
}

/// A backend panic must be contained to its pool worker: the pool
/// marks that worker dead (with the reason in `PoolStats`), reroutes
/// the worker's other adapters, and keeps serving them bit-identically
/// — one poisoned tenant cannot take down its neighbours.
#[test]
fn pool_worker_death_is_isolated_and_rerouted() {
    use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
    use irqlora::coordinator::pool::{home_worker, PoolConfig, ServerPool};
    use irqlora::coordinator::AdapterRegistry;
    use irqlora::util::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    const N_WORKERS: usize = 3;

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[16, 4], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("l0.wq.lora_b", Tensor::new(&[4, 16], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.4)));
        nt
    }

    /// `ReferenceBackend` wrapper that panics when the poison adapter
    /// id reaches the forward pass.
    struct PoisonOnAdapter(ReferenceBackend);
    impl ServeBackend for PoisonOnAdapter {
        fn shape(&self) -> (usize, usize, usize) {
            self.0.shape()
        }
        fn forward(
            &mut self,
            name: &str,
            generation: u64,
            weights: &Arc<NamedTensors>,
            tokens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            if name == "poison" {
                panic!("injected backend fault for adapter '{name}'");
            }
            self.0.forward(name, generation, weights, tokens)
        }
    }

    let mut base = NamedTensors::new();
    base.push("embed", Tensor::full(&[8, 8], 0.25));
    let registry = Arc::new(AdapterRegistry::with_capacity(base, (1.0, 1.0), 4));
    registry.register("poison", adapter(1)).unwrap();
    // healthy tenants, including one guaranteed to share the poison
    // adapter's home worker (so rerouting is actually exercised)
    let poison_home = home_worker("poison", N_WORKERS);
    let mut healthy: Vec<String> = (0..4).map(|i| format!("h{i}")).collect();
    let mate = (0..64)
        .map(|i| format!("mate{i}"))
        .find(|n| home_worker(n, N_WORKERS) == poison_home)
        .expect("no adapter id hashed onto the poison worker");
    healthy.push(mate.clone());
    for (i, name) in healthy.iter().enumerate() {
        registry.register(name, adapter(10 + i as u64)).unwrap();
    }

    let reg = registry.clone();
    let pool = ServerPool::spawn_with(
        PoolConfig::new(N_WORKERS, Duration::from_millis(1)),
        registry,
        move |_w| {
            Ok(Box::new(PoisonOnAdapter(ReferenceBackend::new(4, 8, 12, reg.base())))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();

    // pre-death replies for every healthy tenant
    let before: Vec<Vec<f32>> = healthy
        .iter()
        .map(|n| pool.query(n, vec![2, 3]).unwrap().logits)
        .collect();
    assert_eq!(pool.stats().alive(), N_WORKERS);

    // the poison adapter kills its home worker — surfaced as an error,
    // not a hang
    let err = pool.query("poison", vec![1, 2, 3]).unwrap_err();
    assert!(format!("{err:#}").contains("died"), "{err:#}");

    let s = pool.stats();
    assert_eq!(s.alive(), N_WORKERS - 1, "{s:?}");
    let reason = s.workers[poison_home].dead.as_deref().unwrap_or_else(|| {
        panic!("worker {poison_home} (the poison home) should be the dead one: {s:?}")
    });
    // first recorded reason wins a race between the worker's own
    // panic-unwind self-mark and the client observing the dropped reply
    assert!(
        reason.contains("poison") || reason.contains("panicked"),
        "{reason}"
    );

    // every healthy tenant keeps serving, bit-identical to pre-death —
    // including the one whose home worker just died
    for (name, want) in healthy.iter().zip(&before) {
        let r = pool
            .query(name, vec![2, 3])
            .unwrap_or_else(|e| panic!("healthy adapter '{name}' failed after death: {e:#}"));
        assert_eq!(&r.logits, want, "'{name}' changed answers after the worker death");
    }
    let s = pool.stats();
    assert!(s.reroutes >= 1, "the dead worker's tenants were not rerouted: {s:?}");
    assert_eq!(s.rejected, 0);
    pool.shutdown();
}

/// Fused-batch blast radius: a poison adapter that panics mid-forward
/// while CO-BATCHED with a healthy adapter in one fused drain kills
/// only its worker. The co-batched healthy requests die with that
/// worker (their handles resolve with the death error — nothing
/// hangs), and every SUBSEQUENT request for the co-batched adapter
/// reroutes to a surviving worker with bit-identical logits.
#[test]
fn poison_inside_fused_batch_kills_worker_cobatched_adapters_reroute() {
    use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
    use irqlora::coordinator::pool::{home_worker, PoolConfig, ServerPool};
    use irqlora::coordinator::{AdapterRegistry, BatchServer, ServerConfig};
    use irqlora::util::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    const N_WORKERS: usize = 3;

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[16, 4], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("l0.wq.lora_b", Tensor::new(&[4, 16], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.4)));
        nt
    }

    struct PoisonOnAdapter(ReferenceBackend);
    impl ServeBackend for PoisonOnAdapter {
        fn shape(&self) -> (usize, usize, usize) {
            self.0.shape()
        }
        fn forward(
            &mut self,
            name: &str,
            generation: u64,
            weights: &Arc<NamedTensors>,
            tokens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            if name == "poison" {
                panic!("injected backend fault for adapter '{name}'");
            }
            self.0.forward(name, generation, weights, tokens)
        }
        // no forward_fused override: the default per-group scatter
        // runs, so the panic fires INSIDE the fused call — exactly the
        // blast radius under test
    }

    let mut base = NamedTensors::new();
    base.push("embed", Tensor::full(&[8, 8], 0.25));
    let registry = Arc::new(AdapterRegistry::with_capacity(base, (1.0, 1.0), 4));
    registry.register("poison", adapter(1)).unwrap();
    // a healthy tenant guaranteed to share the poison adapter's home
    // worker, so the two really co-ride one fused drain
    let poison_home = home_worker("poison", N_WORKERS);
    let mate = (0..64)
        .map(|i| format!("mate{i}"))
        .find(|n| home_worker(n, N_WORKERS) == poison_home)
        .expect("no adapter id hashed onto the poison worker");
    registry.register(&mate, adapter(2)).unwrap();

    // serial solo oracle for the mate's expected logits
    let mate_prompt = vec![3, 1, 4];
    let expected = {
        let reg = registry.clone();
        let solo = BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(1)).serial(),
            registry.clone(),
            move || {
                Ok(Box::new(ReferenceBackend::new(4, 8, 12, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        let logits = solo.query(&mate, mate_prompt.clone()).unwrap().logits;
        solo.shutdown();
        logits
    };

    let reg = registry.clone();
    let pool = ServerPool::spawn_with(
        // 500ms window: both submissions below land in ONE drain
        PoolConfig::new(N_WORKERS, Duration::from_millis(500)),
        registry,
        move |_w| {
            Ok(Box::new(PoisonOnAdapter(ReferenceBackend::new(4, 8, 12, reg.base())))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();

    // co-batch: mate first, poison second — one fused drain on the
    // shared home worker
    let mate_h = pool.submit_async(&mate, mate_prompt.clone()).unwrap();
    let poison_h = pool.submit_async("poison", vec![1, 2]).unwrap();
    assert_eq!(mate_h.worker(), poison_home);
    assert_eq!(poison_h.worker(), poison_home);

    let poison_err = poison_h.wait().unwrap_err();
    assert!(format!("{poison_err:#}").contains("died"), "{poison_err:#}");
    // the co-batched healthy request died WITH the worker — resolved,
    // not hung
    let mate_err = mate_h.wait().unwrap_err();
    assert!(format!("{mate_err:#}").contains("died"), "{mate_err:#}");

    let s = pool.stats();
    assert_eq!(s.alive(), N_WORKERS - 1, "{s:?}");
    assert!(s.workers[poison_home].dead.is_some(), "{s:?}");

    // subsequent traffic for the co-batched adapter reroutes and is
    // bit-identical to the serial oracle
    let r = pool.query(&mate, mate_prompt).unwrap();
    assert_eq!(r.logits, expected, "rerouted mate diverged from the oracle");
    assert!(pool.stats().reroutes >= 1, "{:?}", pool.stats());
    pool.shutdown();
}

/// Liveness: a request PARKED in the steal overflow must never hang
/// its handle, even when EVERY worker dies before an idle worker
/// pulls it — the last observed death purges the parked queues, so
/// `wait()` resolves with an error (this test completing at all is
/// the property). Self-skips when `IRQLORA_SERVE_STEAL=0` pins the
/// legacy scheduler (which has no parking).
#[test]
fn parked_request_resolves_even_when_every_worker_dies() {
    use irqlora::coordinator::backend::ServeBackend;
    use irqlora::coordinator::pool::{home_worker, PoolConfig, ServerPool};
    use irqlora::coordinator::AdapterRegistry;
    use irqlora::util::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    if !irqlora::coordinator::serve_steal() {
        return;
    }

    /// Panics on EVERY forward: whichever worker serves anything dies.
    struct AlwaysPanics;
    impl ServeBackend for AlwaysPanics {
        fn shape(&self) -> (usize, usize, usize) {
            (2, 4, 8)
        }
        fn forward(
            &mut self,
            name: &str,
            _generation: u64,
            _weights: &Arc<NamedTensors>,
            _tokens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            panic!("injected: every forward dies ('{name}')");
        }
    }

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[16, 4], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("l0.wq.lora_b", Tensor::new(&[4, 16], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.4)));
        nt
    }

    let mut base = NamedTensors::new();
    base.push("embed", Tensor::full(&[4, 4], 0.5));
    let registry = Arc::new(AdapterRegistry::with_capacity(base, (1.0, 1.0), 4));
    registry.register("a", adapter(1)).unwrap();
    // an adapter homed on the OTHER worker, to kill it too
    let other = (0..64)
        .map(|i| format!("o{i}"))
        .find(|n| home_worker(n, 2) != home_worker("a", 2))
        .expect("no adapter id hashed onto the second worker");
    registry.register(&other, adapter(2)).unwrap();

    let mut cfg = PoolConfig::new(2, Duration::from_millis(1));
    cfg.spill_depth = Some(1); // the second submit for 'a' parks
    let pool = ServerPool::spawn_with(cfg, registry, |_w| {
        Ok(Box::new(AlwaysPanics) as Box<dyn ServeBackend>)
    })
    .unwrap();
    assert!(pool.stealing());

    let q1 = pool.submit_async("a", vec![1]).unwrap(); // direct; kills a's home
    let q2 = pool.submit_async("a", vec![2]).unwrap(); // depth 1 ≥ 1: PARKS
    // aim a third request at the second worker so it dies too (it may
    // already have died stealing q2 — then this submit observes that
    // death at WorkerGone and may itself park on the first worker, to
    // be resolved by the purge below)
    let q3 = pool.submit_async(&other, vec![3]);

    // observe the first worker's death FIRST: once both deaths are
    // recorded, the last one purges the parked overflow
    let e1 = q1.wait().unwrap_err();
    assert!(format!("{e1:#}").contains("died"), "{e1:#}");
    match q3 {
        Ok(h) => {
            let _ = h.wait(); // death or purged-park error — never a hang
        }
        Err(_) => {} // both deaths already observed at submit time
    }

    // the parked handle MUST resolve (stolen-then-dropped, or purged
    // by the last death) — before the fix this wait() hung forever
    let e2 = q2.wait().unwrap_err();
    let msg = format!("{e2:#}");
    assert!(
        msg.contains("dropped") || msg.contains("died"),
        "unexpected parked-request error: {msg}"
    );

    // the pool stays in a clean terminal state: nothing parked, all
    // dead, new submits error instead of blocking
    let s = pool.stats();
    assert_eq!(s.alive(), 0, "{s:?}");
    assert_eq!(s.parked, 0, "{s:?}");
    assert!(pool.query("a", vec![5]).is_err());
    pool.shutdown();
}
