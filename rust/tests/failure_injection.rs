//! Failure injection: the coordinator must fail loudly and precisely,
//! never silently miscompute — missing artifacts, wrong shapes, wrong
//! dtypes, corrupt checkpoints, oversized requests, and a serving
//! backend that panics mid-pool (the blast radius must stop at its
//! worker).

use irqlora::model::{checkpoint, weights::NamedTensors};
use irqlora::runtime::{Dtype, GraphSpec, HostTensor, InputSpec, Manifest, Runtime};
use irqlora::util::Tensor;

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

#[test]
fn missing_artifact_dir_is_clear_error() {
    let err = Manifest::load("/tmp/definitely-not-artifacts-xyz").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "{msg}");
}

#[test]
fn missing_hlo_file_mentions_make_artifacts() {
    let Some(_) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let spec = GraphSpec {
        file: "artifacts/no_such_graph.hlo.txt".into(),
        inputs: vec![],
        n_outputs: 1,
    };
    let err = match rt.load(&spec) {
        Err(e) => e,
        Ok(_) => panic!("loading a missing artifact should fail"),
    };
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn wrong_input_count_rejected() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();
    let err = exe.call(&[HostTensor::F32(vec![0.0; 64])]).unwrap_err();
    assert!(format!("{err:#}").contains("expects 2 inputs"));
}

#[test]
fn wrong_shape_rejected_with_name() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();
    let err = exe
        .call(&[
            HostTensor::F32(vec![0.0; 63]), // should be 64
            HostTensor::F32(vec![0.0; 201]),
        ])
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("block") && msg.contains("63"), "{msg}");
}

#[test]
fn wrong_dtype_rejected() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();
    let err = exe
        .call(&[
            HostTensor::I32(vec![0; 64]), // f32 expected
            HostTensor::F32(vec![0.0; 201]),
        ])
        .unwrap_err();
    assert!(format!("{err:#}").contains("dtype"));
}

#[test]
fn corrupt_hlo_text_rejected() {
    let Some(_) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let path = std::env::temp_dir().join(format!("bad_{}.hlo.txt", std::process::id()));
    std::fs::write(&path, "HloModule garbage\nENTRY { this is not hlo }").unwrap();
    let spec = GraphSpec {
        file: path.clone(),
        inputs: vec![InputSpec { name: "x".into(), shape: vec![1], dtype: Dtype::F32 }],
        n_outputs: 1,
    };
    assert!(rt.load(&spec).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn truncated_checkpoint_detected() {
    let mut nt = NamedTensors::new();
    nt.push("w", Tensor::full(&[256], 1.5));
    let path = std::env::temp_dir().join(format!("trunc_{}.irqc", std::process::id()));
    checkpoint::save(&nt, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
    assert!(checkpoint::load(&path).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn server_rejects_oversized_prompt_without_crashing() {
    let Some(m) = manifest() else { return };
    use irqlora::coordinator::{AdapterRegistry, BatchServer, ServerConfig};
    use irqlora::model::weights::{init_base, init_lora};
    use irqlora::util::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    let tag = "xs";
    let size = m.size(tag).unwrap().clone();
    let spec = m.graph(tag, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(1);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = m.graph(tag, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora = init_lora(&tspec.inputs[nb..nb + nl], size.config.rank, &mut rng);

    let registry = Arc::new(AdapterRegistry::new(base, (0.0, 0.0)));
    registry.register("default", lora).unwrap();
    let server = BatchServer::spawn(
        m,
        tag,
        ServerConfig { max_wait: Duration::from_millis(1) },
        registry,
    )
    .unwrap();

    // oversized prompt -> rejected at submit, before any batch slot
    let err = server.query("default", vec![1; size.config.seq + 5]).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"));
    // empty prompt -> rejected at submit
    assert!(server.query("default", vec![]).is_err());
    // unknown adapter -> rejected at submit
    assert!(server.query("ghost", vec![1, 2, 3]).is_err());
    assert_eq!(server.stats().rejected, 3);
    // server still healthy afterwards
    let ok = server.query("default", vec![1, 8, 70, 70, 4, 3]).unwrap();
    assert_eq!(ok.logits.len(), size.config.vocab);
    server.shutdown();
}

/// A backend panic must be contained to its pool worker: the pool
/// marks that worker dead (with the reason in `PoolStats`), reroutes
/// the worker's other adapters, and keeps serving them bit-identically
/// — one poisoned tenant cannot take down its neighbours.
#[test]
fn pool_worker_death_is_isolated_and_rerouted() {
    use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
    use irqlora::coordinator::pool::{home_worker, PoolConfig, ServerPool};
    use irqlora::coordinator::AdapterRegistry;
    use irqlora::util::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    const N_WORKERS: usize = 3;

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[16, 4], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("l0.wq.lora_b", Tensor::new(&[4, 16], rng.normal_vec(64, 0.0, 0.4)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.4)));
        nt
    }

    /// `ReferenceBackend` wrapper that panics when the poison adapter
    /// id reaches the forward pass.
    struct PoisonOnAdapter(ReferenceBackend);
    impl ServeBackend for PoisonOnAdapter {
        fn shape(&self) -> (usize, usize, usize) {
            self.0.shape()
        }
        fn forward(
            &mut self,
            name: &str,
            generation: u64,
            weights: &Arc<NamedTensors>,
            tokens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            if name == "poison" {
                panic!("injected backend fault for adapter '{name}'");
            }
            self.0.forward(name, generation, weights, tokens)
        }
    }

    let mut base = NamedTensors::new();
    base.push("embed", Tensor::full(&[8, 8], 0.25));
    let registry = Arc::new(AdapterRegistry::with_capacity(base, (1.0, 1.0), 4));
    registry.register("poison", adapter(1)).unwrap();
    // healthy tenants, including one guaranteed to share the poison
    // adapter's home worker (so rerouting is actually exercised)
    let poison_home = home_worker("poison", N_WORKERS);
    let mut healthy: Vec<String> = (0..4).map(|i| format!("h{i}")).collect();
    let mate = (0..64)
        .map(|i| format!("mate{i}"))
        .find(|n| home_worker(n, N_WORKERS) == poison_home)
        .expect("no adapter id hashed onto the poison worker");
    healthy.push(mate.clone());
    for (i, name) in healthy.iter().enumerate() {
        registry.register(name, adapter(10 + i as u64)).unwrap();
    }

    let reg = registry.clone();
    let pool = ServerPool::spawn_with(
        PoolConfig::new(N_WORKERS, Duration::from_millis(1)),
        registry,
        move |_w| {
            Ok(Box::new(PoisonOnAdapter(ReferenceBackend::new(4, 8, 12, reg.base())))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();

    // pre-death replies for every healthy tenant
    let before: Vec<Vec<f32>> = healthy
        .iter()
        .map(|n| pool.query(n, vec![2, 3]).unwrap().logits)
        .collect();
    assert_eq!(pool.stats().alive(), N_WORKERS);

    // the poison adapter kills its home worker — surfaced as an error,
    // not a hang
    let err = pool.query("poison", vec![1, 2, 3]).unwrap_err();
    assert!(format!("{err:#}").contains("died"), "{err:#}");

    let s = pool.stats();
    assert_eq!(s.alive(), N_WORKERS - 1, "{s:?}");
    let reason = s.workers[poison_home].dead.as_deref().unwrap_or_else(|| {
        panic!("worker {poison_home} (the poison home) should be the dead one: {s:?}")
    });
    assert!(reason.contains("poison"), "{reason}");

    // every healthy tenant keeps serving, bit-identical to pre-death —
    // including the one whose home worker just died
    for (name, want) in healthy.iter().zip(&before) {
        let r = pool
            .query(name, vec![2, 3])
            .unwrap_or_else(|e| panic!("healthy adapter '{name}' failed after death: {e:#}"));
        assert_eq!(&r.logits, want, "'{name}' changed answers after the worker death");
    }
    let s = pool.stats();
    assert!(s.reroutes >= 1, "the dead worker's tenants were not rerouted: {s:?}");
    assert_eq!(s.rejected, 0);
    pool.shutdown();
}
