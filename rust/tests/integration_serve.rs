//! Integration: the dynamic-batching server under concurrent load
//! with real PJRT artifacts — correct replies, actual batching,
//! multi-adapter routing, clean shutdown. Self-skips without
//! `make artifacts` (the offline routing coverage lives in
//! multi_adapter_serve.rs over the reference backend).

use std::sync::Arc;
use std::time::Duration;

use irqlora::coordinator::{AdapterRegistry, BatchServer, ServerConfig};
use irqlora::data::evalset::mmlu_item;
use irqlora::data::World;
use irqlora::model::weights::{init_base, init_lora};
use irqlora::runtime::Manifest;
use irqlora::util::Rng;

/// Spawn a PJRT server with `n_adapters` registered tenants
/// ("tenant0".. differ in their random LoRA init).
fn spawn_server(
    max_wait: Duration,
    n_adapters: usize,
) -> Option<(BatchServer, usize, usize)> {
    let m = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping serve tests: {e}");
            return None;
        }
    };
    let tag = "xs";
    let size = m.size(tag).unwrap().clone();
    let spec = m.graph(tag, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(21);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = m.graph(tag, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora_specs = tspec.inputs[nb..nb + nl].to_vec();

    let registry = Arc::new(AdapterRegistry::new(base, (1.0, 1.0)));
    for i in 0..n_adapters {
        let mut arng = Rng::new(100 + i as u64);
        let mut lora = init_lora(&lora_specs, size.config.rank, &mut arng);
        // init_lora zeroes lora_b/betas (identity adapter); give each
        // tenant a distinct nonzero adapter so routing is observable
        let names: Vec<String> = lora.names().to_vec();
        for name in names {
            if name.ends_with("lora_b") || name == "betas" {
                let t = lora.get_mut(&name).unwrap();
                for v in t.data_mut() {
                    *v = arng.normal() * 0.05;
                }
            }
        }
        registry.register(&format!("tenant{i}"), lora).unwrap();
    }

    let server = BatchServer::spawn(
        m,
        tag,
        ServerConfig::new(max_wait),
        registry,
    )
    .unwrap();
    Some((server, size.config.vocab, size.config.batch))
}

#[test]
fn single_request_roundtrip() {
    let Some((server, vocab, _)) = spawn_server(Duration::from_millis(1), 1) else {
        return;
    };
    let world = World::new(1);
    let mut rng = Rng::new(1);
    let item = mmlu_item(&world, 0, &mut rng, 5);
    let reply = server.query("tenant0", item.prompt.clone()).unwrap();
    assert_eq!(reply.adapter, "tenant0");
    assert_eq!(reply.logits.len(), vocab);
    assert!(reply.logits.iter().all(|x| x.is_finite()));
    assert!(reply.batch_size >= 1);
    server.shutdown();
}

#[test]
fn replies_match_request_not_batchmate() {
    // two different prompts served concurrently must get *different*
    // logits (guards against row-swap bugs in the batcher)
    let Some((server, _, _)) = spawn_server(Duration::from_millis(20), 1) else {
        return;
    };
    let server = Arc::new(server);
    let world = World::new(2);
    let mut rng = Rng::new(2);
    let p1 = mmlu_item(&world, 0, &mut rng, 5).prompt;
    let p2 = mmlu_item(&world, 1, &mut rng, 2).prompt; // different length too
    assert_ne!(p1, p2);

    let s1 = server.clone();
    let h1 = std::thread::spawn(move || s1.query("tenant0", p1).unwrap());
    let s2 = server.clone();
    let h2 = std::thread::spawn(move || s2.query("tenant0", p2).unwrap());
    let r1 = h1.join().unwrap();
    let r2 = h2.join().unwrap();
    let diff: f32 = r1
        .logits
        .iter()
        .zip(&r2.logits)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-3, "different prompts produced identical logits");
}

#[test]
fn mixed_adapter_batch_each_gets_own_logits() {
    // one prompt through 3 different adapters concurrently: each
    // reply must match that adapter's solo answer, and distinct
    // adapters (nonzero, independently-random LoRA) must disagree
    let Some((server, _, _)) = spawn_server(Duration::from_millis(30), 3) else {
        return;
    };
    let server = Arc::new(server);
    let world = World::new(7);
    let mut rng = Rng::new(7);
    let prompt = mmlu_item(&world, 1, &mut rng, 5).prompt;

    // solo oracles first (sequential, one request per batch)
    let solo: Vec<Vec<f32>> = (0..3)
        .map(|i| server.query(&format!("tenant{i}"), prompt.clone()).unwrap().logits)
        .collect();

    let mut handles = Vec::new();
    for i in 0..3 {
        let server = server.clone();
        let prompt = prompt.clone();
        handles.push(std::thread::spawn(move || {
            server.query(&format!("tenant{i}"), prompt).unwrap()
        }));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.join().unwrap();
        assert_eq!(r.adapter, format!("tenant{i}"));
        for (a, b) in r.logits.iter().zip(&solo[i]) {
            assert!((a - b).abs() < 1e-5, "tenant{i} contaminated under mixed load");
        }
    }
    // the adapters genuinely disagree on this prompt
    let d01: f32 = solo[0].iter().zip(&solo[1]).map(|(a, b)| (a - b).abs()).sum();
    assert!(d01 > 1e-4, "tenant0/tenant1 adapters served identical logits");
    let stats = server.stats();
    assert_eq!(stats.per_adapter.len(), 3);
    server.shutdown();
}

#[test]
fn concurrent_load_batches_requests() {
    let Some((server, _, max_batch)) = spawn_server(Duration::from_millis(30), 1) else {
        return;
    };
    let server = Arc::new(server);
    let world = World::new(3);
    let n = 32usize;
    let mut handles = Vec::new();
    for i in 0..n {
        let server = server.clone();
        let mut rng = Rng::new(100 + i as u64);
        let prompt = mmlu_item(&world, i % 4, &mut rng, 5).prompt;
        handles.push(std::thread::spawn(move || {
            server.query("tenant0", prompt).unwrap()
        }));
    }
    let replies: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let stats = server.stats();
    assert_eq!(stats.requests, n);
    // with 32 concurrent clients and a 30ms window, batching must occur
    assert!(
        stats.batches < n,
        "no batching happened: {} batches for {n} requests",
        stats.batches
    );
    assert!(stats.mean_batch_size() > 1.2);
    assert!(replies.iter().all(|r| r.batch_size <= max_batch));
}

#[test]
fn deterministic_same_prompt_same_logits() {
    let Some((server, _, _)) = spawn_server(Duration::from_millis(1), 1) else {
        return;
    };
    let world = World::new(4);
    let mut rng = Rng::new(4);
    let prompt = mmlu_item(&world, 2, &mut rng, 5).prompt;
    let a = server.query("tenant0", prompt.clone()).unwrap();
    let b = server.query("tenant0", prompt).unwrap();
    for (x, y) in a.logits.iter().zip(&b.logits) {
        assert!((x - y).abs() < 1e-5);
    }
    server.shutdown();
}
