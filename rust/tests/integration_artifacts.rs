//! Cross-language parity: AOT HLO kernel artifacts vs the Rust
//! implementations of the same math. These tests require
//! `make artifacts` to have been run (skipped with a clear message
//! otherwise) and exercise the full path rust -> PJRT -> HLO -> host.

use irqlora::quant::{blockwise, entropy, nf};
use irqlora::runtime::{HostTensor, Manifest, Runtime};
use irqlora::util::Rng;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping artifact tests: {e}");
            None
        }
    }
}

#[test]
fn icq_entropy_kernel_matches_rust() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("icq_entropy").unwrap()).unwrap();

    let mut rng = Rng::new(101);
    let block: Vec<f32> = (0..64).map(|_| rng.normal_ms(0.01, 0.03)).collect();
    let taus: Vec<f32> = (0..201).map(|i| -0.09 + 0.2 * i as f32 / 200.0).collect();

    let outs = exe
        .call_f32(&[
            HostTensor::F32(block.clone()),
            HostTensor::F32(taus.clone()),
        ])
        .unwrap();
    let hlo_entropies = &outs[0];
    assert_eq!(hlo_entropies.len(), 201);

    // Rust oracle: same sweep
    let cb = nf::codebook(4);
    let bounds = nf::boundaries(&cb);
    for (i, &tau) in taus.iter().enumerate() {
        let mut amax = 0f32;
        for &x in &block {
            amax = amax.max((x - tau).abs());
        }
        let mut counts = [0u32; 16];
        for &x in &block {
            counts[nf::quantize_one(&bounds, (x - tau) / amax) as usize] += 1;
        }
        let h = irqlora::util::stats::entropy_bits(&counts) as f32;
        assert!(
            (h - hlo_entropies[i]).abs() < 1e-4,
            "tau[{i}]={tau}: rust {h} vs hlo {}",
            hlo_entropies[i]
        );
    }
}

#[test]
fn quant_block_kernel_matches_rust() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(m.kernel("quant_block").unwrap()).unwrap();

    let mut rng = Rng::new(102);
    let w: Vec<f32> = (0..1024 * 64).map(|_| rng.normal_ms(0.0, 0.05)).collect();

    let outs = exe.call(&[HostTensor::F32(w.clone())]).unwrap();
    let codes = outs[0].as_u8().unwrap().to_vec();
    let scales = outs[1].as_f32().unwrap().to_vec();

    let q = blockwise::quantize(&w, 4, 64, None);
    assert_eq!(codes, q.codes, "codes must match bit-exactly");
    for (a, b) in scales.iter().zip(&q.scales) {
        assert!((a - b).abs() < 1e-7);
    }
    // and entropy computed from either side agrees
    let h = entropy::code_entropy(&codes, 4);
    assert!((h - entropy::code_entropy(&q.codes, 4)).abs() < 1e-12);
}
