//! Integration: the full coordinator pipeline against real AOT
//! artifacts — pretraining learns, finetuning learns, quantizer arms
//! compose, LoRA-at-init is an exact identity through the graphs,
//! and the merged-IEC serving contract holds end to end.
//!
//! All tests no-op with a note if `make artifacts` hasn't run.

use irqlora::coordinator::{quantize_model, Evaluator, Finetuner, Pretrainer};
use irqlora::data::evalset::mmlu_set;
use irqlora::data::instruct::{instruct_batch, Dataset};
use irqlora::data::{corpus, World};
use irqlora::model::weights::{init_base, init_lora};
use irqlora::quant::Method;
use irqlora::runtime::{Manifest, Runtime};
use irqlora::util::Rng;

fn setup() -> Option<(Manifest, Runtime)> {
    match Manifest::load("artifacts") {
        Ok(m) => Some((m, Runtime::cpu().unwrap())),
        Err(e) => {
            eprintln!("skipping integration tests: {e}");
            None
        }
    }
}

const TAG: &str = "xs";

#[test]
fn pretrain_loss_decreases() {
    let Some((m, rt)) = setup() else { return };
    let world = World::new(11);
    let size = m.size(TAG).unwrap();
    let mut rng = Rng::new(11);
    let mut pre = Pretrainer::new(&rt, &m, TAG, 11).unwrap();
    for _ in 0..25 {
        let b = corpus::pretrain_batch(&world, &mut rng, size.config.batch, size.config.seq);
        pre.step(b.tokens, b.targets).unwrap();
    }
    let first = pre.losses[0];
    let last = *pre.losses.last().unwrap();
    assert!(
        last < first * 0.7,
        "pretraining failed to learn: {first} -> {last}"
    );
    assert!(pre.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn finetune_learns_and_all_arms_match_shapes() {
    let Some((m, rt)) = setup() else { return };
    let world = World::new(12);
    let size = m.size(TAG).unwrap();
    let spec = m.graph(TAG, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(12);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);

    for method in [Method::Nf { k: 4 }, Method::NfIcq { k: 4 }, Method::Int { k: 4 }] {
        let qm = quantize_model(&base, method, 12).unwrap();
        let mut ft = Finetuner::new(&rt, &m, TAG, &qm.dequantized, (1.0, 1.0), 12).unwrap();
        let mut drng = Rng::new(13);
        for _ in 0..8 {
            let b = instruct_batch(
                &world, Dataset::AlpacaSyn, &mut drng, size.config.batch, size.config.seq,
            );
            ft.step(b.tokens, b.targets).unwrap();
        }
        let first = ft.losses[0];
        let last = *ft.losses.last().unwrap();
        assert!(last < first, "{method:?}: loss {first} -> {last}");
    }
}

#[test]
fn iec_masks_change_training_dynamics() {
    let Some((m, rt)) = setup() else { return };
    let world = World::new(14);
    let size = m.size(TAG).unwrap();
    let spec = m.graph(TAG, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(14);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let qm = quantize_model(&base, Method::Nf { k: 4 }, 14).unwrap();

    let run = |masks: (f32, f32)| -> Vec<f32> {
        let mut ft = Finetuner::new(&rt, &m, TAG, &qm.dequantized, masks, 14).unwrap();
        let mut drng = Rng::new(15);
        for _ in 0..5 {
            let b = instruct_batch(
                &world, Dataset::AlpacaSyn, &mut drng, size.config.batch, size.config.seq,
            );
            ft.step(b.tokens, b.targets).unwrap();
        }
        // betas live in the last lora tensor
        ft.lora.get("betas").unwrap().data().to_vec()
    };
    let betas_off = run((0.0, 0.0));
    let betas_on = run((1.0, 1.0));
    // with masks off, beta gradients are zero -> betas stay 0
    assert!(betas_off.iter().all(|&b| b == 0.0), "masked-off betas moved");
    // with masks on, betas receive gradient and move
    assert!(betas_on.iter().any(|&b| b != 0.0), "masked-on betas frozen");
}

#[test]
fn lora_identity_at_init_through_graphs() {
    let Some((m, rt)) = setup() else { return };
    // evaluation with freshly-initialized adapters must match for any
    // mask setting (adapter contributes exactly zero at init)
    let world = World::new(16);
    let size = m.size(TAG).unwrap();
    let spec = m.graph(TAG, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(16);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = m.graph(TAG, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora = init_lora(&tspec.inputs[nb..nb + nl], size.config.rank, &mut rng);

    let items = mmlu_set(&world, 6, 16);
    let ev_off = Evaluator::new(&rt, &m, TAG, &base, &lora, (0.0, 0.0)).unwrap();
    let ev_on = Evaluator::new(&rt, &m, TAG, &base, &lora, (1.0, 1.0)).unwrap();
    let refs: Vec<&irqlora::data::evalset::McItem> = items.iter().take(4).collect();
    let a = ev_off.score_batch(&refs).unwrap();
    let b = ev_on.score_batch(&refs).unwrap();
    for (ra, rb) in a.iter().zip(&b) {
        for (x, y) in ra.iter().zip(rb) {
            assert!((x - y).abs() < 1e-4, "identity at init violated: {x} vs {y}");
        }
    }
}

#[test]
fn quantized_eval_close_to_fp_at_4bit() {
    let Some((m, rt)) = setup() else { return };
    // 4-bit NF quantization of a RANDOM (untrained) model must leave
    // next-token logits close to the fp32 ones (sanity on the whole
    // dequantize -> forward path)
    let world = World::new(17);
    let size = m.size(TAG).unwrap();
    let spec = m.graph(TAG, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(17);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let qm = quantize_model(&base, Method::Nf { k: 4 }, 17).unwrap();

    let tspec = m.graph(TAG, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora = init_lora(&tspec.inputs[nb..nb + nl], size.config.rank, &mut rng);

    let items = mmlu_set(&world, 4, 17);
    let refs: Vec<&irqlora::data::evalset::McItem> = items.iter().take(4).collect();
    let ev_fp = Evaluator::new(&rt, &m, TAG, &base, &lora, (0.0, 0.0)).unwrap();
    let ev_q = Evaluator::new(&rt, &m, TAG, &qm.dequantized, &lora, (0.0, 0.0)).unwrap();
    let a = ev_fp.score_batch(&refs).unwrap();
    let b = ev_q.score_batch(&refs).unwrap();
    let mut max_rel = 0f32;
    for (ra, rb) in a.iter().zip(&b) {
        let scale = ra.iter().fold(0f32, |m, x| m.max(x.abs())).max(1e-3);
        for (x, y) in ra.iter().zip(rb) {
            max_rel = max_rel.max((x - y).abs() / scale);
        }
    }
    assert!(max_rel < 0.35, "4-bit logit drift too large: {max_rel}");
}

#[test]
fn evaluator_scores_deterministic() {
    let Some((m, rt)) = setup() else { return };
    let world = World::new(18);
    let size = m.size(TAG).unwrap();
    let spec = m.graph(TAG, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(18);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = m.graph(TAG, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora = init_lora(&tspec.inputs[nb..nb + nl], size.config.rank, &mut rng);
    let ev = Evaluator::new(&rt, &m, TAG, &base, &lora, (0.0, 0.0)).unwrap();
    let items = mmlu_set(&world, 5, 18);
    let r1 = ev.evaluate(&items).unwrap();
    let r2 = ev.evaluate(&items).unwrap();
    assert_eq!(format!("{:?}", r1.per_group), format!("{:?}", r2.per_group));
}
