//! Chaos soak battery: the sharded serving pool under seeded
//! deterministic fault injection ([`FaultBackend`]), asserting the
//! robustness contract end to end for several fixed seeds:
//!
//! - **liveness**: every submitted handle resolves (no hangs) even
//!   with injected errors, latency, and one worker allowed to panic;
//! - **bounded memory**: the parked-overflow peak never exceeds the
//!   configured `park_bound`, and an open-loop submitter is shed with
//!   `Overloaded` instead of growing queues;
//! - **correctness under faults**: every delivered reply — including
//!   every per-step logit row of the multi-step decode streams mixed
//!   into the load — is bit-identical to a clean single-worker serial
//!   oracle replaying the stream's greedy prefix at that step;
//! - **honest accounting**: `PoolStats` shed/retry counters reconcile
//!   exactly against the outcomes observed on the client side;
//! - **graceful degradation**: healthy tenants keep getting answers
//!   (throughput > 0 across ≥ 2 distinct adapters).
//!
//! The fault schedule is a pure function of the seed (see
//! `coordinator::chaos`), so each `#[test]` here replays the same
//! injected-fault sequence on every run.
//!
//! The faulted pool's inner backends come from the HAL registry's
//! validated factory for `IRQLORA_SERVE_BACKEND` (default
//! `reference`); `scripts/verify.sh` reruns this file with
//! `IRQLORA_SERVE_BACKEND=native` so the chaos contract is asserted
//! over the native CPU backend too. The clean serial oracle stays
//! pinned to `ReferenceBackend` regardless, so delivered-reply
//! bit-identity is checked *across* backends, not just within one.

use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
use irqlora::coordinator::pool::{PoolConfig, ServerPool};
use irqlora::hal::{BackendRegistry, BackendRequest};
use irqlora::coordinator::{
    greedy_next_token, synthetic_serve_registry, BatchServer, FaultBackend, FaultConfig,
    FaultStats, ServeError, ServerConfig,
};
use irqlora::telemetry;
use irqlora::util::Rng;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const WORKERS: usize = 3;
const TENANTS: usize = 6;
const REQUESTS: usize = 300;
const PARK_BOUND: usize = 8;
const BATCH: usize = 8;
const SEQ: usize = 32;
const VOCAB: usize = 64;
/// Fixture seed for the registry weights — deliberately NOT the chaos
/// seed, so the oracle registry is reproducible independently.
const FIXTURE_SEED: u64 = 7;

/// Value of `key` in a snapshot (0 when absent — a counter that never
/// fired is equivalent to one resolved at 0).
fn telem_value(entries: &[telemetry::SnapshotEntry], key: &str) -> u64 {
    entries.iter().find(|e| e.key == key).map_or(0, |e| e.value)
}

fn soak(seed: u64) {
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    // scoped ENABLED telemetry registry with a JSONL sink, injected
    // through PoolConfig (never process env — tests run in parallel):
    // after the soak its counters must reconcile EXACTLY with
    // PoolStats/FaultStats, and the JSONL's final snapshot must
    // round-trip the live snapshot
    let jsonl_path = std::env::temp_dir().join(format!(
        "irqlora_chaos_telem_{}_{seed}.jsonl",
        std::process::id()
    ));
    std::fs::remove_file(&jsonl_path).ok(); // appender appends: drop stale runs
    let treg = Arc::new(telemetry::Registry::enabled().with_jsonl(&jsonl_path));
    let mut pcfg = PoolConfig::new(WORKERS, Duration::from_millis(1));
    pcfg.spill_depth = Some(2);
    pcfg.park_bound = Some(PARK_BOUND);
    pcfg.park_age = Some(Duration::from_millis(5));
    pcfg.telemetry = Some(treg.clone());
    // faulted workers wrap whatever backend the env selects, built
    // through the manifest-validated HAL factory — a bad name or an
    // unsupported shape fails here with a typed error, not mid-soak
    let backend_name = irqlora::util::env::serve_backend();
    let mut req = BackendRequest::new(BATCH, SEQ, VOCAB);
    req.workers = WORKERS;
    let make_inner = BackendRegistry::builtin()
        .pool_factory(&backend_name, &req, registry.base().clone(), "soak")
        .unwrap_or_else(|e| panic!("backend '{backend_name}' rejected for soak: {e}"));
    let fault_stats: Arc<Mutex<Vec<Arc<FaultStats>>>> = Arc::new(Mutex::new(Vec::new()));
    let fs = fault_stats.clone();
    let treg_w = treg.clone();
    let pool = ServerPool::spawn_with(pcfg, registry, move |w| {
        // worker 0 keeps its seed-derived panic knob (death + reroute
        // under load); the others must survive the whole soak
        let cfg = if w == 0 {
            FaultConfig::from_seed(seed)
        } else {
            FaultConfig::from_seed(seed ^ w as u64).no_panic()
        };
        let fb = FaultBackend::with_telemetry(make_inner(w)?, cfg, &treg_w);
        fs.lock().unwrap().push(fb.stats());
        Ok(Box::new(fb) as Box<dyn ServeBackend>)
    })
    .unwrap();

    // open-loop skewed load: half the traffic on one hot tenant, every
    // 4th request with a tight deadline, every 5th a multi-step decode
    // STREAM (riding the same deadlines, so mid-stream shedding under
    // chaos is reachable); nothing is drained until all submissions
    // are in, so overload shedding is actually reachable
    let mut rng = Rng::new(seed.wrapping_mul(0x9e3779b97f4a7c15) ^ 0xc0ffee);
    let mut handles = Vec::new();
    let (mut overloaded, mut shed_at_submit, mut refused_dead) = (0usize, 0usize, 0usize);
    let mut streams_admitted = 0usize;
    for i in 0..REQUESTS {
        let tenant = if rng.chance(0.5) {
            "tenant0".to_string()
        } else {
            format!("tenant{}", 1 + rng.below(TENANTS - 1))
        };
        let len = 1 + rng.below(8);
        let prompt: Vec<i32> = (0..len).map(|_| 1 + rng.below(VOCAB - 1) as i32).collect();
        let deadline = (i % 4 == 3).then(|| Instant::now() + Duration::from_millis(5));
        let steps = if i % 5 == 0 { 2 + rng.below(3) } else { 1 };
        match pool.submit_stream_with_deadline(&tenant, prompt.clone(), steps, deadline) {
            Ok(p) => {
                streams_admitted += (steps > 1) as usize;
                handles.push((tenant, prompt, steps, p));
            }
            Err(ServeError::Overloaded { depth, retry_after_hint }) => {
                assert!(depth > 0, "seed={seed}: Overloaded with empty overflow");
                assert!(
                    retry_after_hint > Duration::ZERO,
                    "seed={seed}: useless retry hint"
                );
                overloaded += 1;
            }
            Err(ServeError::DeadlineExceeded { .. }) => shed_at_submit += 1,
            Err(e @ ServeError::WorkerDead { .. }) => {
                assert!(e.retryable(), "seed={seed}: WorkerDead must be retryable");
                refused_dead += 1;
            }
            Err(e) => panic!("seed={seed}: unexpected submit error: {e}"),
        }
    }

    // liveness: every handle must resolve well inside the timeout —
    // streams step by step, the greedy prefix recorded per step so the
    // oracle can replay it. `delivered` holds every Ok logit row as
    // (tenant, exact tokens the row was computed for, logits).
    let mut delivered: Vec<(String, Vec<i32>, Vec<f32>)> = Vec::new();
    let (mut completed, mut ddl, mut faulted, mut dead) = (0usize, 0usize, 0usize, 0usize);
    let (mut ok_replies, mut ddl_midstream, mut streams_with_step) = (0usize, 0usize, 0usize);
    for (tenant, prompt, steps, mut h) in handles {
        let mut prefix = prompt;
        let mut steps_seen = 0usize;
        let terminal = loop {
            let r = h.wait_timeout(Duration::from_secs(30)).unwrap_or_else(|| {
                panic!("seed={seed}: a handle never resolved — liveness lost")
            });
            match r {
                Ok(reply) => {
                    steps_seen += 1;
                    assert_eq!(reply.step, steps_seen, "seed={seed}: steps out of order");
                    assert_eq!(reply.last, steps_seen == steps, "seed={seed}");
                    ok_replies += 1;
                    delivered.push((tenant.clone(), prefix.clone(), reply.logits.clone()));
                    if reply.last {
                        break Ok(());
                    }
                    prefix.push(greedy_next_token(&reply.logits));
                }
                Err(e) => break Err(e),
            }
        };
        let got_any = steps_seen > 0;
        streams_with_step += (steps > 1 && got_any) as usize;
        match terminal {
            Ok(()) => completed += 1,
            Err(ServeError::DeadlineExceeded { .. }) => {
                ddl += 1;
                ddl_midstream += got_any as usize;
            }
            Err(ServeError::BackendFault(msg)) => {
                assert!(msg.contains("chaos"), "seed={seed}: non-injected fault: {msg}");
                faulted += 1;
            }
            Err(ServeError::WorkerDead { .. }) => dead += 1,
            Err(e) => panic!("seed={seed}: unexpected terminal error: {e}"),
        }
    }

    let stats = pool.stats();
    pool.shutdown();

    // every submitted request is accounted for exactly once
    assert_eq!(
        completed + ddl + faulted + dead + overloaded + shed_at_submit + refused_dead,
        REQUESTS,
        "seed={seed}: outcomes do not partition the request stream"
    );

    // graceful degradation: the pool kept answering through the chaos
    assert!(!delivered.is_empty(), "seed={seed}: nothing delivered");
    let distinct: std::collections::BTreeSet<&str> =
        delivered.iter().map(|(t, _, _)| t.as_str()).collect();
    assert!(
        distinct.len() >= 2,
        "seed={seed}: only {distinct:?} got answers — healthy tenants starved"
    );

    // bounded memory: the CAS-reserved park bound is exact, and all
    // parked work was drained or purged by harvest time
    assert!(
        stats.parked_peak <= PARK_BOUND,
        "seed={seed}: parked depth peaked at {} > bound {PARK_BOUND}",
        stats.parked_peak
    );
    assert_eq!(stats.parked, 0, "seed={seed}: requests left parked after harvest");

    // honest accounting: counters reconcile against observed outcomes
    // (every shed path counts before it answers, so by the time the
    // client sees the error the counter is visible)
    assert_eq!(
        stats.shed_overload, overloaded,
        "seed={seed}: shed_overload disagrees with observed Overloaded refusals"
    );
    assert_eq!(
        stats.shed_deadline,
        ddl + shed_at_submit,
        "seed={seed}: shed_deadline disagrees with observed DeadlineExceeded outcomes"
    );
    // step-level accounting: a decode step is counted exactly when a
    // step reply is delivered; a mid-stream shed is exactly a
    // DeadlineExceeded terminal after ≥ 1 delivered step
    assert_eq!(
        stats.steps, ok_replies,
        "seed={seed}: steps counter disagrees with delivered step replies"
    );
    assert_eq!(
        stats.shed_midstream, ddl_midstream,
        "seed={seed}: shed_midstream disagrees with streams shed after a step"
    );
    assert!(
        stats.shed_midstream <= stats.shed_deadline,
        "seed={seed}: shed_midstream must be a subset of shed_deadline"
    );
    // a stream is counted at its first decode step, so the counter is
    // bracketed by streams that produced a step (a first fused attempt
    // can fault without delivering) and streams admitted at submit
    assert!(
        streams_with_step <= stats.stream_requests
            && stats.stream_requests <= streams_admitted,
        "seed={seed}: stream_requests {} outside [{streams_with_step}, {streams_admitted}]",
        stats.stream_requests
    );
    assert!(
        stats.retries <= REQUESTS * (WORKERS + 2),
        "seed={seed}: retry counter {} exceeds any sane budget",
        stats.retries
    );
    // only worker 0 may panic; the pool must not lose anyone else
    let dead_workers =
        stats.workers.iter().enumerate().filter(|(_, w)| w.dead.is_some()).count();
    assert!(dead_workers <= 1, "seed={seed}: {dead_workers} workers died (only 0 may)");
    if let Some(w) = stats.workers.iter().position(|w| w.dead.is_some()) {
        assert_eq!(w, 0, "seed={seed}: a no_panic worker died: {:?}", stats.workers[w].dead);
    }

    // the schedule really injected faults (this is a chaos soak, not a
    // clean run): the busiest backend saw enough calls to fault
    let injected = fault_stats.lock().unwrap();
    let total_errors: u64 = injected.iter().map(|s| s.errors()).sum();
    let total_forwards: u64 = injected.iter().map(|s| s.forwards()).sum();
    assert!(total_forwards > 0, "seed={seed}: no forwards reached the backends");
    assert!(total_errors > 0, "seed={seed}: the chaos schedule never fired");

    // telemetry reconciliation: the scoped registry's counters were
    // incremented at the SAME mutation sites as the struct stats, so
    // they must agree EXACTLY — any drift means a mirror is missing
    // or double-counted
    let snap = treg.snapshot();
    let tv = |key: &str| telem_value(&snap, key);
    assert_eq!(tv("serve.requests"), stats.requests as u64, "seed={seed}: serve.requests");
    assert_eq!(tv("serve.batches"), stats.batches as u64, "seed={seed}: serve.batches");
    assert_eq!(
        tv("serve.fused_batches"),
        stats.fused_batches as u64,
        "seed={seed}: serve.fused_batches"
    );
    assert_eq!(tv("serve.rejected"), stats.rejected as u64, "seed={seed}: serve.rejected");
    assert_eq!(
        tv("pool.shed_overload"),
        stats.shed_overload as u64,
        "seed={seed}: pool.shed_overload"
    );
    assert_eq!(
        tv("pool.shed_deadline") + tv("serve.shed_deadline"),
        stats.shed_deadline as u64,
        "seed={seed}: shed_deadline views disagree"
    );
    assert_eq!(tv("serve.steps"), stats.steps as u64, "seed={seed}: serve.steps");
    assert_eq!(
        tv("serve.stream_requests"),
        stats.stream_requests as u64,
        "seed={seed}: serve.stream_requests"
    );
    assert_eq!(
        tv("serve.shed_midstream"),
        stats.shed_midstream as u64,
        "seed={seed}: serve.shed_midstream"
    );
    assert_eq!(tv("pool.retries"), stats.retries as u64, "seed={seed}: pool.retries");
    assert_eq!(tv("pool.steals"), stats.steals as u64, "seed={seed}: pool.steals");
    assert_eq!(tv("pool.reroutes"), stats.reroutes as u64, "seed={seed}: pool.reroutes");
    assert_eq!(tv("pool.spills"), stats.spills as u64, "seed={seed}: pool.spills");
    assert_eq!(
        tv("pool.parked_peak"),
        stats.parked_peak as u64,
        "seed={seed}: pool.parked_peak"
    );
    assert_eq!(
        tv("serve.upload{event=hit}"),
        stats.upload_hits as u64,
        "seed={seed}: upload hit deltas must telescope to the stats snapshot"
    );
    assert_eq!(
        tv("serve.upload{event=miss}"),
        stats.upload_misses as u64,
        "seed={seed}: upload miss deltas"
    );
    // per-adapter: every tenant's telemetry counter matches its slice
    for (name, a) in &stats.per_adapter {
        assert_eq!(
            tv(&format!("serve.adapter_requests{{adapter={name}}}")),
            a.requests as u64,
            "seed={seed}: adapter_requests for {name}"
        );
    }
    // chaos.* mirrors FaultStats exactly (summed across workers)
    assert_eq!(tv("chaos.forwards"), total_forwards, "seed={seed}: chaos.forwards");
    assert_eq!(
        tv("chaos.step_forwards"),
        injected.iter().map(|s| s.steps()).sum::<u64>(),
        "seed={seed}: chaos.step_forwards"
    );
    assert_eq!(tv("chaos.errors_injected"), total_errors, "seed={seed}: chaos.errors");
    assert_eq!(
        tv("chaos.panics_injected"),
        injected.iter().map(|s| s.panics()).sum::<u64>(),
        "seed={seed}: chaos.panics"
    );
    assert_eq!(
        tv("chaos.delays_injected"),
        injected.iter().map(|s| s.delays()).sum::<u64>(),
        "seed={seed}: chaos.delays"
    );

    // JSONL sink: the final flushed snapshot must round-trip the live
    // snapshot bit-for-bit (scoped registries have no background
    // flusher — the explicit flush IS the final snapshot)
    treg.flush_jsonl().expect("flushing telemetry JSONL");
    let last = telemetry::read_last_snapshot(&jsonl_path)
        .unwrap_or_else(|| panic!("seed={seed}: no well-formed snapshot in {jsonl_path:?}"));
    assert_eq!(
        last.entries, snap,
        "seed={seed}: JSONL final snapshot diverges from the live registry"
    );
    std::fs::remove_file(&jsonl_path).ok();

    // correctness: every delivered logit row (one-shot replies AND
    // each stream step, keyed by the exact prefix it was computed for)
    // is bit-identical to a clean serial single-worker oracle over an
    // identically-built registry
    let oracle_reg = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let oreg = oracle_reg.clone();
    let oracle = BatchServer::spawn_with(
        ServerConfig::new(Duration::from_millis(1)).serial(),
        oracle_reg,
        move || {
            Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, oreg.base()))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();
    for (tenant, prompt, logits) in &delivered {
        let want = oracle.query(tenant, prompt.clone()).unwrap().logits;
        assert_eq!(
            logits, &want,
            "seed={seed}: '{tenant}' diverged from the serial oracle under chaos"
        );
    }
    oracle.shutdown();
}

/// Steal-then-shed: with stealing ON, slow workers, and tight
/// deadlines on an open-loop burst, requests are shed wherever they
/// sit — at submit, parked, stolen onto another worker's queue, or in
/// a drained batch — and every shed is counted EXACTLY once across the
/// pool/server `shed_deadline` split, reconciling with the client-side
/// outcome partition. (This is the fold the telemetry wiring clones
/// per-view; any double-count or missed mirror breaks the equalities.)
#[test]
fn steal_then_shed_counts_every_deadline_exactly_once() {
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let treg = Arc::new(telemetry::Registry::enabled());
    let mut pcfg = PoolConfig::new(2, Duration::from_millis(1));
    pcfg.steal = true;
    pcfg.park_bound = Some(4);
    pcfg.park_age = Some(Duration::from_millis(1));
    pcfg.telemetry = Some(treg.clone());
    let reg = registry.clone();
    let pool = ServerPool::spawn_with(pcfg, registry, move |_w| {
        Ok(Box::new(
            ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())
                .with_forward_delay(Duration::from_millis(8)),
        ) as Box<dyn ServeBackend>)
    })
    .unwrap();

    let mut handles = Vec::new();
    let (mut shed_submit, mut overloaded) = (0usize, 0usize);
    const BURST: usize = 120;
    for i in 0..BURST {
        // two tenants so one worker can sit idle and steal
        let tenant = format!("tenant{}", i % 2);
        let deadline = (i % 2 == 1).then(|| Instant::now() + Duration::from_millis(12));
        match pool.submit_with_deadline(&tenant, vec![1 + (i % 8) as i32], deadline) {
            Ok(p) => handles.push(p),
            Err(ServeError::DeadlineExceeded { .. }) => shed_submit += 1,
            Err(ServeError::Overloaded { .. }) => overloaded += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let (mut delivered, mut ddl) = (0usize, 0usize);
    for mut h in handles {
        match h.wait_timeout(Duration::from_secs(30)).expect("liveness lost") {
            Ok(_) => delivered += 1,
            Err(ServeError::DeadlineExceeded { .. }) => ddl += 1,
            Err(e) => panic!("unexpected terminal error: {e}"),
        }
    }

    let stats = pool.stats();
    assert_eq!(
        delivered + ddl + shed_submit + overloaded,
        BURST,
        "outcomes do not partition the burst: {stats:?}"
    );
    assert!(delivered > 0, "nothing delivered: {stats:?}");
    assert!(
        ddl + shed_submit > 0,
        "no deadline ever fired — the scenario lost its teeth: {stats:?}"
    );
    assert_eq!(
        stats.shed_deadline,
        ddl + shed_submit,
        "a shed was dropped or double-counted: {stats:?}"
    );
    assert_eq!(stats.shed_midstream, 0, "one-shot load cannot shed mid-stream: {stats:?}");
    let snap = treg.snapshot();
    let tv = |key: &str| telem_value(&snap, key);
    assert_eq!(
        tv("pool.shed_deadline") + tv("serve.shed_deadline"),
        stats.shed_deadline as u64,
        "the two shed_deadline views do not sum to the fold"
    );
    assert_eq!(tv("serve.steps"), stats.steps as u64, "serve.steps");
    assert_eq!(stats.steps, delivered, "each one-shot delivery is exactly one step");
    pool.shutdown();
}

#[test]
fn chaos_soak_seed_11() {
    soak(11);
}

#[test]
fn chaos_soak_seed_23() {
    soak(23);
}

#[test]
fn chaos_soak_seed_47() {
    soak(47);
}
