//! The packed GEMM path must compute on quantized storage, not around
//! it — ISSUE acceptance: "the packed path never allocates a full
//! dequantized weight matrix".
//!
//! Enforced with a counting `#[global_allocator]` (same harness as
//! `telemetry_disabled.rs`), at two operating points:
//!
//! - **serial path** (shapes under `IRQLORA_GEMM_SERIAL_BELOW`
//!   multiply-adds): with warm `y`/scratch buffers, a steady-state
//!   `gemm_packed_into` window must see exactly ZERO heap
//!   acquisitions — the per-block LUT lives on the stack;
//! - **parallel path**: the worker fan-out may allocate bookkeeping
//!   (thread handles), but the bytes acquired per call must stay far
//!   below `rows·cols·4` — the cost of materializing the dequantized
//!   f32 matrix even once.
//!
//! This file deliberately holds ONE `#[test]` — a sibling test's
//! thread would allocate inside the measurement window and turn the
//! asserts flaky.

use irqlora::kernels::{gemm_packed_into, PackedGemmScratch};
use irqlora::quant::QuantizedTensor;
use irqlora::{Rng, Tensor};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// `System` with acquisition odometers (count + bytes). Frees are not
/// counted — the contract under test is about acquisitions.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn packed_gemm_never_materializes_the_dequantized_matrix() {
    let mut rng = Rng::new(0xA110C);
    let mut y = Vec::new();
    let mut scratch = PackedGemmScratch::new();

    // --- serial path: 16×64 = 1024 madds, under the 8192 default ---
    let (rows, cols) = (16usize, 64usize);
    let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.8));
    let qt = QuantizedTensor::quantize(&w, 4, 64, None);
    let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
    // warm-up: sizes the buffers, latches the env knobs and resolves
    // the (no-op) telemetry handles — all one-time costs by contract
    gemm_packed_into(&qt, &x, &mut y, &mut scratch);
    let y0 = y.clone();

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..100 {
        gemm_packed_into(&qt, &x, &mut y, &mut scratch);
    }
    let grew = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        grew, 0,
        "steady-state serial packed matvec acquired heap {grew} times — \
         the packed kernel's hot path must be allocation-free"
    );
    // and the answers stayed the answers
    for (i, (a, b)) in y.iter().zip(&y0).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row {i} drifted across the window");
    }

    // --- parallel path: 256×512 = 131072 madds, well over 8192 ---
    let (rows, cols) = (256usize, 512usize);
    let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.8));
    let qt = QuantizedTensor::quantize(&w, 2, 64, None);
    let x: Vec<f32> = rng.normal_vec(cols, 0.0, 1.0);
    gemm_packed_into(&qt, &x, &mut y, &mut scratch); // warm for this shape
    let matrix_bytes = (rows * cols * std::mem::size_of::<f32>()) as u64;

    let before = BYTES.load(Ordering::SeqCst);
    gemm_packed_into(&qt, &x, &mut y, &mut scratch);
    let spent = BYTES.load(Ordering::SeqCst) - before;
    assert!(
        spent < matrix_bytes,
        "parallel packed matvec acquired {spent} bytes — enough to have \
         materialized the {matrix_bytes}-byte dequantized matrix it is \
         supposed to never build"
    );
}
