//! Property-based tests (seeded random-case runner — proptest is not
//! in the offline vendor set). Each property runs over many random
//! configurations; failures print the case seed for reproduction.

use irqlora::lora::iec::{gcd, lora_iec_forward, u1_elastic, u2_elastic};
use irqlora::lora::merge::{merge_l1, merge_l1_into, merge_l2, merge_l2_into};
use irqlora::quant::{
    blockwise, double_quant::DoubleQuant, entropy, fp8, fused, icq, integer, nf,
    DequantScratch, QuantizedTensor,
};
use irqlora::telemetry::{read_last_snapshot, Registry};
use irqlora::util::f16;
use irqlora::util::{stats, Rng, Tensor};

/// Run `f` over `n` random cases derived from a base seed.
fn cases(n: usize, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..n {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15) ^ (i as u64);
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

#[test]
fn prop_pack_unpack_roundtrip() {
    cases(50, 1, |seed, rng| {
        let k = 1 + rng.below(8) as u8;
        let n = rng.below(2000);
        let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << k) as u8).collect();
        let packed = blockwise::pack_codes(&codes, k);
        let back = blockwise::unpack_codes(&packed, k, n);
        assert_eq!(back, codes, "seed={seed} k={k} n={n}");
    });
}

#[test]
fn prop_quant_error_bounded_by_block_absmax() {
    // |w - dq(q(w))| <= absmax(block) * max_gap(codebook)/2 per element
    cases(30, 2, |seed, rng| {
        let k = 2 + rng.below(3) as u8;
        let n = 64 * (1 + rng.below(20));
        let scale = rng.range_f32(1e-3, 10.0);
        let w: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.0, scale)).collect();
        let q = blockwise::quantize(&w, k, 64, None);
        let wh = blockwise::dequantize(&q);
        let cb = nf::codebook(k);
        let max_gap = cb.windows(2).map(|p| p[1] - p[0]).fold(0f32, f32::max);
        for (bi, chunk) in w.chunks(64).enumerate() {
            let amax = stats::absmax(chunk);
            let bound = amax * max_gap / 2.0 + 1e-6;
            for (i, &x) in chunk.iter().enumerate() {
                let err = (x - wh[bi * 64 + i]).abs();
                assert!(err <= bound, "seed={seed} k={k} block={bi}: {err} > {bound}");
            }
        }
    });
}

#[test]
fn prop_fast_paths_bit_identical_to_reference() {
    // parallel quantize / dequantize / pack / unpack must reproduce the
    // serial reference implementations exactly — codes, scales, and
    // every output f32 bit — for k in 1..=8 including empty inputs,
    // partial last blocks, and zero blocks.
    cases(40, 20, |seed, rng| {
        let k = 1 + rng.below(8) as u8;
        let block = [16usize, 32, 64, 128][rng.below(4)];
        let n = rng.below(5000);
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.01, 0.05)).collect();
        if n > 0 && rng.chance(0.2) {
            // force a zero block at the front
            for x in w.iter_mut().take(block.min(n)) {
                *x = 0.0;
            }
        }
        let n_blocks = n.div_ceil(block);
        let taus: Vec<f32> = (0..n_blocks).map(|_| rng.range_f32(-0.02, 0.02)).collect();
        let taus_opt = if rng.chance(0.5) { Some(taus.as_slice()) } else { None };

        let fast = blockwise::quantize(&w, k, block, taus_opt);
        let refr = blockwise::quantize_reference(&w, k, block, taus_opt);
        assert_eq!(fast.codes, refr.codes, "seed={seed} k={k} n={n}");
        assert_eq!(fast.scales, refr.scales, "seed={seed} k={k} n={n}");

        let d_fast = blockwise::dequantize(&fast);
        let d_ref = blockwise::dequantize_reference(&refr);
        for (i, (a, b)) in d_fast.iter().zip(&d_ref).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "seed={seed} k={k} i={i}");
        }

        let p_fast = blockwise::pack_codes(&fast.codes, k);
        let p_ref = blockwise::pack_codes_reference(&refr.codes, k);
        assert_eq!(p_fast, p_ref, "seed={seed} k={k} n={n}");
        assert_eq!(
            blockwise::unpack_codes(&p_fast, k, n),
            blockwise::unpack_codes_reference(&p_ref, k, n),
            "seed={seed} k={k} n={n}"
        );
    });
}

#[test]
fn prop_fused_packed_dequant_bit_identical() {
    // packed-domain dequantization (LUT / word-at-a-time, parallel or
    // the unaligned serial fallback) must equal unpack + reference
    // dequantize bit-for-bit for k in 1..=8.
    cases(60, 21, |seed, rng| {
        let k = 1 + rng.below(8) as u8;
        // blocks where block*k % 8 may or may not vanish — both the
        // parallel byte-aligned path and the serial fallback get hit
        let block = [7usize, 10, 16, 64, 96][rng.below(5)];
        let n = 1 + rng.below(4000);
        let w: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.0, 0.08)).collect();
        let n_blocks = n.div_ceil(block);
        let taus: Vec<f32> = (0..n_blocks).map(|_| rng.range_f32(-0.03, 0.03)).collect();
        let taus_opt = if rng.chance(0.5) { Some(taus.as_slice()) } else { None };

        let q = blockwise::quantize_reference(&w, k, block, taus_opt);
        let packed = blockwise::pack_codes_reference(&q.codes, k);
        let want = blockwise::dequantize_reference(&q);
        let mut got = vec![0f32; n];
        fused::dequantize_packed_into(
            &packed,
            k,
            n,
            block,
            &q.scales,
            q.taus.as_deref(),
            &mut got,
        );
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "seed={seed} k={k} block={block} n={n} i={i}: {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_quantized_tensor_fused_matches_reference_pipeline() {
    // the full Eq. 10 storage pipeline: fused dequantize (with scratch
    // reuse across iterations) == unpack-everything reference
    let scratch = std::cell::RefCell::new(DequantScratch::default());
    let seen_icq = std::cell::Cell::new(false);
    cases(20, 22, |seed, rng| {
        let k = 2 + rng.below(3) as u8;
        let n = 64 * (1 + rng.below(12)) + rng.below(64);
        let t = Tensor::new(&[n], (0..n).map(|_| rng.normal_ms(0.01, 0.04)).collect());
        let icq_cfg = icq::IcqConfig { n: 10, ..Default::default() };
        let use_icq = rng.chance(0.4);
        let q = QuantizedTensor::quantize(&t, k, 64, use_icq.then_some(&icq_cfg));
        let want = q.dequantize_reference();
        let mut got = vec![0f32; n];
        q.dequantize_into(&mut got, &mut scratch.borrow_mut());
        for (i, (a, b)) in got.iter().zip(want.data()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "seed={seed} k={k} n={n} i={i}");
        }
        seen_icq.set(seen_icq.get() | use_icq);
    });
    assert!(seen_icq.get(), "expected at least one ICQ case");
}

#[test]
fn prop_merge_into_matches_alloc_variant() {
    // scratch-reuse merge == allocating merge across random dims
    let dims = [4usize, 6, 8, 12, 16, 24, 32];
    let scratch = std::cell::RefCell::new((Vec::new(), Vec::new()));
    cases(30, 23, |seed, rng| {
        let h = *rng.pick(&dims);
        let r = *rng.pick(&dims[..4]);
        let o = *rng.pick(&dims);
        let l1 = rng.normal_vec(h * r, 0.0, 0.2);
        let l2 = rng.normal_vec(r * o, 0.0, 0.2);
        let (b1, b2) = (rng.normal(), rng.normal());
        let mut s = scratch.borrow_mut();
        let (m1, m2) = &mut *s;
        merge_l1_into(&l1, h, r, b1, m1);
        merge_l2_into(&l2, r, o, b2, m2);
        assert_eq!(*m1, merge_l1(&l1, h, r, b1), "seed={seed} h={h} r={r}");
        assert_eq!(*m2, merge_l2(&l2, r, o, b2), "seed={seed} r={r} o={o}");
    });
}

#[test]
fn prop_icq_entropy_at_least_vanilla_on_average() {
    // across random shifted distributions, mean ICQ entropy must not
    // lose to vanilla (the paper's core claim, Figure 4)
    cases(15, 3, |seed, rng| {
        let shift = rng.range_f32(-0.05, 0.05);
        let scale = rng.range_f32(0.005, 0.1);
        let w: Vec<f32> = (0..64 * 30).map(|_| rng.normal_ms(shift, scale)).collect();
        let q0 = blockwise::quantize(&w, 4, 64, None);
        let q1 = icq::quantize(&w, 4, 64, &icq::IcqConfig::default());
        let h0 = entropy::mean_block_entropy(&q0);
        let h1 = entropy::mean_block_entropy(&q1);
        assert!(h1 >= h0 - 1e-6, "seed={seed}: icq {h1} < vanilla {h0}");
    });
}

#[test]
fn prop_iec_merge_equivalence_random_dims() {
    // x·ℓ̃1·ℓ̃2 == U2(U1(x)) for random (h, r, o) triples
    let dims = [4usize, 6, 8, 12, 16, 24, 32, 48, 64];
    cases(40, 4, |seed, rng| {
        let h = *rng.pick(&dims);
        let r = *rng.pick(&dims[..5]);
        let o = *rng.pick(&dims);
        let x = rng.normal_vec(h, 0.0, 1.0);
        let l1 = rng.normal_vec(h * r, 0.0, 0.2);
        let l2 = rng.normal_vec(r * o, 0.0, 0.2);
        let (b1, b2) = (rng.normal(), rng.normal());
        let explicit = lora_iec_forward(&x, &l1, &l2, r, o, 1.0, b1, b2, 1.0, 1.0);
        let m1 = merge_l1(&l1, h, r, b1);
        let m2 = merge_l2(&l2, r, o, b2);
        let merged = lora_iec_forward(&x, &m1, &m2, r, o, 1.0, 0.0, 0.0, 0.0, 0.0);
        for (i, (a, b)) in explicit.iter().zip(&merged).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                "seed={seed} h={h} r={r} o={o} idx={i}: {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_elastic_terms_preserve_mean() {
    cases(40, 5, |seed, rng| {
        let dims = [8usize, 16, 32, 64, 128];
        let h = *rng.pick(&dims);
        let r = *rng.pick(&dims[..3]);
        let x = rng.normal_vec(h, 0.0, 1.0);
        let e1 = u1_elastic(&x, r);
        let m_in = stats::mean(&x);
        let m_out = stats::mean(&e1);
        assert!((m_in - m_out).abs() < 1e-4, "seed={seed}");
        let e2 = u2_elastic(&e1, h);
        assert!((stats::mean(&e2) - m_out).abs() < 1e-4, "seed={seed}");
    });
}

#[test]
fn prop_double_quant_relative_error() {
    cases(30, 6, |seed, rng| {
        let n = 1 + rng.below(600);
        let scale = rng.range_f32(1e-3, 100.0);
        let vals: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 1.0) * scale).collect();
        let dq = DoubleQuant::quantize(&vals, 256);
        for (i, (&a, b)) in vals.iter().zip(dq.dequantize()).enumerate() {
            let rel = ((a - b) / a).abs();
            assert!(rel < 0.08, "seed={seed} i={i}: {a} -> {b} ({rel})");
        }
    });
}

#[test]
fn prop_fp8_f16_monotone_rounding() {
    // quantize-dequantize must be monotone (order-preserving)
    cases(20, 7, |seed, rng| {
        let mut xs: Vec<f32> = (0..200).map(|_| rng.normal_ms(0.0, 50.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let e4m3: Vec<f32> = xs.iter().map(|&x| fp8::round_e4m3(x)).collect();
        let h: Vec<f32> = xs.iter().map(|&x| f16::round_f16(x)).collect();
        for w in e4m3.windows(2) {
            assert!(w[0] <= w[1], "seed={seed}: e4m3 not monotone");
        }
        for w in h.windows(2) {
            assert!(w[0] <= w[1], "seed={seed}: f16 not monotone");
        }
    });
}

#[test]
fn prop_integer_quant_idempotent() {
    // quantizing an already-dequantized tensor is (near) lossless
    cases(25, 8, |seed, rng| {
        let n = 64 * (1 + rng.below(8));
        let w = rng.normal_vec(n, 0.0, 0.1);
        let q1 = integer::quantize(&w, 4, 64);
        let d1 = integer::dequantize(&q1);
        let q2 = integer::quantize(&d1, 4, 64);
        let d2 = integer::dequantize(&q2);
        let err = stats::max_abs_diff(&d1, &d2);
        assert!(err < 1e-5, "seed={seed}: idempotency violated ({err})");
    });
}

#[test]
fn prop_gcd_properties() {
    cases(100, 9, |seed, rng| {
        let a = 1 + rng.below(512);
        let b = 1 + rng.below(512);
        let g = gcd(a, b);
        assert!(g >= 1 && a % g == 0 && b % g == 0, "seed={seed}");
        assert_eq!(gcd(a, b), gcd(b, a));
        assert_eq!(gcd(a, a), a);
    });
}

#[test]
fn prop_malformed_checkpoint_parsing_is_total() {
    use irqlora::model::checkpoint::{
        load, load_with_plan, peek_entries, peek_plan, save, save_with_plan,
    };
    use irqlora::model::NamedTensors;
    use irqlora::precision::{PlanEntry, PrecisionPlan};

    // parsers of `.irqc` bytes must be total: any truncation, bit
    // flip, or crafted header field (absurd counts, lengths, dims)
    // yields Ok or a typed Err — never a panic, hang, or an
    // allocation sized from an unchecked header field
    let tmp = |tag: &str| {
        std::env::temp_dir().join(format!("irqc_prop_{tag}_{}", std::process::id()))
    };
    let saved_bytes = |with_plan: bool| {
        let mut nt = NamedTensors::new();
        nt.push("l0.wq", Tensor::new(&[4, 3], (0..12).map(|i| i as f32 * 0.25).collect()));
        nt.push("bias", Tensor::new(&[5], vec![1.0; 5]));
        let p = tmp(if with_plan { "v2" } else { "v1" });
        if with_plan {
            let plan = PrecisionPlan {
                budget_bits: 3.0,
                block: 64,
                entries: vec![PlanEntry {
                    name: "l0.wq".into(),
                    k: 4,
                    n_params: 12,
                    entropy: 3.1,
                    bits_per_weight: 4.2,
                }],
            };
            save_with_plan(&nt, &plan, &p).unwrap();
        } else {
            save(&nt, &p).unwrap();
        }
        let b = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        b
    };
    let base_v1 = saved_bytes(false);
    let base_v2 = saved_bytes(true);

    let p = tmp("fuzz");
    cases(150, 32, |seed, rng| {
        let mut bytes = if rng.chance(0.5) { base_v1.clone() } else { base_v2.clone() };
        match rng.below(4) {
            0 => {
                // proper-prefix truncation
                bytes.truncate(rng.below(bytes.len()));
            }
            1 => {
                // 1-4 random bit flips anywhere
                for _ in 0..1 + rng.below(4) {
                    let i = rng.below(bytes.len());
                    bytes[i] ^= 1 << rng.below(8);
                }
            }
            2 => {
                // overwrite a length/count-bearing u32 with an absurd
                // value (count @8, plan_len / first name_len @12,
                // or any aligned field)
                let off = *rng.pick(&[8usize, 12, 16, 4 * rng.below(bytes.len() / 4)]);
                let off = off.min(bytes.len() - 4);
                let v: u32 = match rng.below(3) {
                    0 => u32::MAX,
                    1 => 1 << 31,
                    _ => rng.below(1 << 30) as u32,
                };
                bytes[off..off + 4].copy_from_slice(&v.to_le_bytes());
            }
            _ => {
                // append trailing garbage (readers must not trust EOF
                // position as a validity signal)
                let extra = 1 + rng.below(64);
                bytes.extend((0..extra).map(|_| rng.below(256) as u8));
            }
        }
        std::fs::write(&p, &bytes).unwrap();
        let l = load(&p);
        let lp = load_with_plan(&p);
        let _ = peek_entries(&p);
        let _ = peek_plan(&p);
        assert_eq!(l.is_ok(), lp.is_ok(), "seed={seed}: load vs load_with_plan disagree");
    });
    // truncations specifically must always fail the checksum-validated
    // reader, at every cut of both formats
    for base in [&base_v1, &base_v2] {
        for cut in [0, 3, 7, 11, 12, 15, base.len() / 2, base.len() - 1] {
            std::fs::write(&p, &base[..cut]).unwrap();
            assert!(load(&p).is_err(), "cut={cut} loaded");
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn prop_pool_affinity_deterministic_and_balanced() {
    use irqlora::coordinator::pool::home_worker;
    // adapter-affinity routing must be a pure function of (adapter id,
    // pool size) — stable across calls and processes — and a uniform
    // population of adapter ids must spread within 2x of the ideal
    // per-worker load (the consistent-hash quality the merged-weight
    // and device-buffer caches rely on).
    cases(20, 30, |seed, rng| {
        let n = 1 + rng.below(8);
        for _ in 0..32 {
            let len = 1 + rng.below(24);
            let id: String = (0..len)
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            let h = home_worker(&id, n);
            assert!(h < n, "seed={seed} n={n} id={id}: {h} out of range");
            assert_eq!(h, home_worker(&id, n), "seed={seed}: routing not deterministic");
        }
        // balance over distinct uniform ids
        let per_worker = 200usize;
        let mut counts = vec![0usize; n];
        for i in 0..per_worker * n {
            counts[home_worker(&format!("adapter-{seed}-{i}"), n)] += 1;
        }
        let max = counts.iter().copied().max().unwrap();
        assert!(
            max <= 2 * per_worker,
            "seed={seed} n={n}: worst worker got {max} of ideal {per_worker}: {counts:?}"
        );
        if n > 1 {
            let min = counts.iter().copied().min().unwrap();
            assert!(min > 0, "seed={seed} n={n}: a worker got no adapters: {counts:?}");
        }
    });
}

#[test]
fn prop_native_backend_bit_identical_to_reference() {
    use irqlora::coordinator::backend::{AdapterGroup, ReferenceBackend, ServeBackend};
    use irqlora::coordinator::quantize_model;
    use irqlora::hal::NativeBackend;
    use irqlora::model::weights::NamedTensors;
    use irqlora::quant::Method;
    use std::sync::Arc;

    // the native cache-blocked backend must be bit-identical to the
    // reference oracle for every logit — across k in {2,3,4,8} (its
    // streaming tile constructor really dequantizes packed NF-k
    // storage, including partial last blocks), arbitrary shapes,
    // partial batches (trailing all-PAD rows), ragged rows, and
    // multi-group fused forwards with unowned gap rows
    cases(12, 33, |seed, rng| {
        let k = *rng.pick(&[2u8, 3, 4, 8]);
        let batch = 2 + rng.below(6);
        let seq = 1 + rng.below(24);
        let vocab = 2 + rng.below(150);

        let mut base = NamedTensors::new();
        let n0 = 64 * (1 + rng.below(6)) + rng.below(64); // partial last block
        base.push("l0.wq", Tensor::new(&[n0], rng.normal_vec(n0, 0.0, 0.05)));
        base.push("embed", Tensor::new(&[33], rng.normal_vec(33, 0.0, 0.1)));
        let qm = quantize_model(&base, Method::NfIcq { k }, seed ^ 9).unwrap();
        assert!(!qm.storage.is_empty(), "seed={seed}: no packed storage to stream from");

        let mut native = NativeBackend::from_quantized(batch, seq, vocab, &qm);
        let mut reference = ReferenceBackend::new(batch, seq, vocab, &qm.dequantized);

        // two adapters' merged weights — contents arbitrary, only the
        // fingerprints matter to both backends
        let weights: Vec<Arc<NamedTensors>> = (0..2)
            .map(|_| {
                let mut aw = NamedTensors::new();
                aw.push("l0.wq", Tensor::new(&[16], rng.normal_vec(16, 0.0, 0.3)));
                Arc::new(aw)
            })
            .collect();

        // partial batch: only the first `rows` rows carry tokens,
        // with ragged per-row lengths (PAD tails)
        let rows = 1 + rng.below(batch);
        let mut tokens = vec![irqlora::data::PAD; batch * seq];
        for b in 0..rows {
            let len = 1 + rng.below(seq);
            for slot in tokens[b * seq..].iter_mut().take(len) {
                *slot = 1 + rng.below(200) as i32;
            }
        }

        let got = native.forward("a", 1, &weights[0], &tokens).unwrap();
        let want = reference.forward("a", 1, &weights[0], &tokens).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "seed={seed} k={k} batch={batch} seq={seq} vocab={vocab} i={i}: {g} vs {w}"
            );
        }

        // fused: two groups over the occupied rows, with an unowned
        // gap row whenever the partial batch leaves room for one
        let split = 1 + rng.below(rows.max(2) - 1).min(rows - 1);
        let groups = vec![
            AdapterGroup {
                name: "a".into(),
                generation: 1,
                weights: weights[0].clone(),
                rows: 0..split,
            },
            AdapterGroup {
                name: "b".into(),
                generation: 3,
                weights: weights[1].clone(),
                rows: split..rows,
            },
        ];
        let got = native.forward_fused(&groups, &tokens).unwrap();
        let want = reference.forward_fused(&groups, &tokens).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "seed={seed} k={k} fused i={i}");
        }
        assert_eq!(
            native.upload_stats(),
            reference.upload_stats(),
            "seed={seed}: adapter-cache accounting diverged"
        );
    });
}

#[test]
fn prop_entropy_bounds_and_permutation_invariance() {
    cases(30, 10, |seed, rng| {
        let k = 2 + rng.below(3) as u8;
        let n = 1 + rng.below(500);
        let mut codes: Vec<u8> = (0..n).map(|_| rng.below(1 << k) as u8).collect();
        let h1 = entropy::code_entropy(&codes, k);
        assert!(h1 >= 0.0 && h1 <= k as f64 + 1e-9, "seed={seed}");
        rng.shuffle(&mut codes);
        let h2 = entropy::code_entropy(&codes, k);
        assert!((h1 - h2).abs() < 1e-12, "seed={seed}: entropy not permutation-invariant");
    });
}

#[test]
fn prop_fused_slot_plan_order_and_bounds() {
    use irqlora::coordinator::fused_slot_plan;
    // for any drained request sequence (the worker never hands over
    // more than max_batch requests), the fused slot plan must: cover
    // every request exactly once, keep submit order within each
    // adapter, keep groups in first-arrival order, and assign row
    // spans that never exceed max_batch.
    cases(40, 31, |seed, rng| {
        let max_batch = 1 + rng.below(16);
        let n = 1 + rng.below(max_batch);
        let n_adapters = 1 + rng.below(6);
        let ids: Vec<String> =
            (0..n).map(|_| format!("t{}", rng.below(n_adapters))).collect();
        let refs: Vec<&str> = ids.iter().map(String::as_str).collect();
        let plan = fused_slot_plan(&refs);

        // total coverage, each request exactly once
        let mut seen: Vec<usize> = plan.iter().flat_map(|(_, idx)| idx.clone()).collect();
        assert_eq!(seen.len(), n, "seed={seed}: row count != request count");
        assert!(seen.len() <= max_batch, "seed={seed}: exceeded max_batch");
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "seed={seed}: not a permutation");

        let mut first_arrival_prev = None;
        for (adapter, idx) in &plan {
            // submit order preserved within the adapter
            for w in idx.windows(2) {
                assert!(w[0] < w[1], "seed={seed} {adapter}: submit order broken");
            }
            // indices really belong to this adapter
            for &i in idx {
                assert_eq!(&refs[i], adapter, "seed={seed}: wrong group for request {i}");
            }
            // groups appear in first-arrival order
            if let Some(prev) = first_arrival_prev {
                assert!(idx[0] > prev, "seed={seed}: groups out of arrival order");
            }
            first_arrival_prev = Some(idx[0]);
        }
        // one group per distinct adapter
        let distinct: std::collections::BTreeSet<&&str> =
            refs.iter().collect();
        assert_eq!(plan.len(), distinct.len(), "seed={seed}");
    });
}

#[test]
fn prop_jsonl_roundtrip_survives_adversarial_labels() {
    // Adapter names flow into telemetry label values, so any byte soup
    // must survive Appender -> read_last_snapshot with exactly the
    // documented sanitization (quote / backslash / control -> '_') —
    // and must never forge or shadow a neighbouring line's fields,
    // even when the label spells out field names like `value: 99`.
    const NASTY: &[char] = &[
        'a', 'Z', '9', '"', '\\', '\n', '\t', '{', '}', ',', ':', ' ', '.', 'é', '→',
        'v', 'l', 'u', 'e', 's', 'n', 'p', 'h', 'o', 't',
    ];
    let path = std::env::temp_dir()
        .join(format!("irqlora_prop_jsonl_{}.jsonl", std::process::id()));
    cases(40, 77, |seed, rng| {
        let _ = std::fs::remove_file(&path);
        let r = Registry::enabled().with_jsonl(&path);
        let n_labels = 1 + rng.below(3);
        let mut wanted: Vec<(String, u64)> = Vec::new();
        for li in 0..n_labels {
            let len = 1 + rng.below(16);
            let val: String = (0..len).map(|_| NASTY[rng.below(NASTY.len())]).collect();
            let v = rng.below(10_000) as u64 + 1;
            let li_s = li.to_string();
            r.counter("prop.requests", &[("adapter", val.as_str()), ("i", li_s.as_str())])
                .add(v);
            let sanitized: String = val
                .chars()
                .map(|c| if c == '"' || c == '\\' || c.is_control() { '_' } else { c })
                .collect();
            wanted.push((format!("prop.requests{{adapter={sanitized},i={li}}}"), v));
        }
        r.counter("prop.sentinel", &[]).add(7);
        r.flush_jsonl().unwrap();

        let last =
            read_last_snapshot(&path).unwrap_or_else(|| panic!("seed={seed}: unreadable file"));
        for (key, v) in &wanted {
            let e = last.entries.iter().find(|e| &e.key == key).unwrap_or_else(|| {
                panic!(
                    "seed={seed}: key {key:?} missing from {:?}",
                    last.entries.iter().map(|e| &e.key).collect::<Vec<_>>()
                )
            });
            assert_eq!(e.value, *v, "seed={seed} key={key:?}");
        }
        let s = last.entries.iter().find(|e| e.key == "prop.sentinel").unwrap();
        assert_eq!(s.value, 7, "seed={seed}: sentinel shadowed by adversarial neighbour");
    });
    let _ = std::fs::remove_file(&path);
}
