//! Streaming-decode battery: continuous batching end to end.
//!
//! - **bit-identity**: every per-step logit row of every concurrent
//!   stream is bit-identical to BOTH the one-shot fused answer for the
//!   greedy-extended prefix at that step and a clean serial per-group
//!   oracle, for k ∈ {2, 3, 4, 8} mixed-adapter stream sets — and the
//!   serial-mode (`fused: false`) scheduler reproduces the fused-mode
//!   streams exactly;
//! - **mid-stream deadline shed**: a stream whose deadline expires
//!   after it has produced tokens terminates with `DeadlineExceeded`,
//!   is counted once in `shed_midstream`, and does NOT poison the
//!   co-batched tenant riding in the same fused steps;
//! - **mid-stream worker death**: an injected panic between decode
//!   steps surfaces as `WorkerDead` on the live iterator after the
//!   already-delivered steps, which remain bit-correct.
//!
//! The oracle strategy mirrors `chaos_soak`: reference logits depend
//! only on (base, adapter, row tokens), so a one-shot query for the
//! prefix a stream had at step j reproduces that step exactly.

use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
use irqlora::coordinator::pool::{PoolConfig, ServerPool};
use irqlora::coordinator::{
    greedy_next_token, synthetic_serve_registry, BatchServer, FaultBackend, FaultConfig,
    ServeError, ServerConfig,
};
use irqlora::telemetry;
use std::time::{Duration, Instant};

const BATCH: usize = 8;
const SEQ: usize = 32;
const VOCAB: usize = 64;
const TENANTS: usize = 8;
const FIXTURE_SEED: u64 = 7;

fn serial_oracle() -> BatchServer {
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let reg = registry.clone();
    BatchServer::spawn_with(
        ServerConfig::new(Duration::from_millis(1)).serial(),
        registry,
        move || {
            Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap()
}

/// (tenant, prompt, steps) for `k` concurrent mixed-adapter streams.
fn stream_specs(k: usize) -> Vec<(String, Vec<i32>, usize)> {
    (0..k)
        .map(|i| {
            let tenant = format!("tenant{}", i % TENANTS);
            let prompt: Vec<i32> = (0..2 + i % 3)
                .map(|t| (1 + (i * 13 + t * 5) % (VOCAB - 1)) as i32)
                .collect();
            (tenant, prompt, 3 + i % 4)
        })
        .collect()
}

/// Drive every spec as a live stream on `pool`, concurrently (so the
/// streams actually co-batch), returning each stream's per-step logits.
fn drive_streams(
    pool: &ServerPool,
    specs: &[(String, Vec<i32>, usize)],
) -> Vec<Vec<Vec<f32>>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|(tenant, prompt, steps)| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let pending = pool.submit_stream(tenant, prompt.clone(), *steps).unwrap();
                    for (j, r) in pending.enumerate() {
                        let r = r.unwrap_or_else(|e| {
                            panic!("stream '{tenant}' step {}: {e}", j + 1)
                        });
                        assert_eq!(r.step, j + 1, "stream '{tenant}'");
                        assert_eq!(r.last, j + 1 == *steps, "stream '{tenant}'");
                        out.push(r.logits);
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// k concurrent mixed-adapter streams on the fused continuous-batching
/// pool: every step bit-identical to the one-shot fused answer AND the
/// serial per-group oracle for the greedy prefix at that step, and the
/// serial-mode scheduler reproduces the fused-mode streams exactly.
#[test]
fn concurrent_streams_match_oneshot_and_serial_oracles() {
    let oracle = serial_oracle();
    for k in [2usize, 3, 4, 8] {
        let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
        let reg = registry.clone();
        let pool = ServerPool::spawn_with(
            PoolConfig::new(2, Duration::from_millis(2)),
            registry,
            move |_w| {
                Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap();

        let specs = stream_specs(k);
        let streamed = drive_streams(&pool, &specs);

        let mut oneshot_queries = 0usize;
        for (si, ((tenant, prompt, steps), stream)) in specs.iter().zip(&streamed).enumerate()
        {
            assert_eq!(stream.len(), *steps, "k={k} stream {si} lost steps");
            let mut prefix = prompt.clone();
            for (j, logits) in stream.iter().enumerate() {
                let serial = oracle.query(tenant, prefix.clone()).unwrap().logits;
                let oneshot = pool.query(tenant, prefix.clone()).unwrap().logits;
                oneshot_queries += 1;
                assert_eq!(logits.len(), serial.len(), "k={k} stream {si}");
                for (i, a) in logits.iter().enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        serial[i].to_bits(),
                        "k={k} stream {si} step {} logit {i}: streamed vs serial oracle",
                        j + 1
                    );
                    assert_eq!(
                        a.to_bits(),
                        oneshot[i].to_bits(),
                        "k={k} stream {si} step {} logit {i}: streamed vs one-shot fused",
                        j + 1
                    );
                }
                prefix.push(greedy_next_token(logits));
            }
        }

        let s = pool.stats();
        let stream_steps: usize = specs.iter().map(|(_, _, n)| *n).sum();
        assert_eq!(s.stream_requests, k, "k={k}: {s:?}");
        assert_eq!(s.steps, stream_steps + oneshot_queries, "k={k}: {s:?}");
        assert_eq!(s.requests, k + oneshot_queries, "k={k}: {s:?}");
        assert_eq!(s.fused_batches, s.batches, "k={k} fell off the fused path: {s:?}");
        pool.shutdown();

        // the serial-mode scheduler (per-group forward per step) must
        // reproduce the fused-mode streams bit-for-bit
        let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
        let reg = registry.clone();
        let mut pcfg = PoolConfig::new(2, Duration::from_millis(2));
        pcfg.fused = false;
        let serial_pool = ServerPool::spawn_with(pcfg, registry, move |_w| {
            Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                as Box<dyn ServeBackend>)
        })
        .unwrap();
        let serial_streamed = drive_streams(&serial_pool, &specs);
        for (si, (fused, serial)) in streamed.iter().zip(&serial_streamed).enumerate() {
            assert_eq!(fused.len(), serial.len(), "k={k} stream {si}");
            for (j, (fl, sl)) in fused.iter().zip(serial).enumerate() {
                for (i, (a, b)) in fl.iter().zip(sl).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "k={k} stream {si} step {} logit {i}: fused vs serial scheduler",
                        j + 1
                    );
                }
            }
        }
        let s = serial_pool.stats();
        assert_eq!(s.fused_batches, 0, "k={k}: serial config used the fused path: {s:?}");
        assert_eq!(s.steps, stream_steps, "k={k}: {s:?}");
        serial_pool.shutdown();
    }
    oracle.shutdown();
}

/// A stream whose deadline expires mid-decode is shed with
/// `DeadlineExceeded` after the steps it already produced, counted
/// once in `shed_midstream` — and the co-batched tenant's stream runs
/// to completion bit-identically (no poisoning).
#[test]
fn midstream_deadline_shed_does_not_poison_cobatched_stream() {
    let oracle = serial_oracle();
    let treg = std::sync::Arc::new(telemetry::Registry::enabled());
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let reg = registry.clone();
    // one worker, a generous fill window (both streams join the first
    // fused step), and a slow backend so the deadline lands mid-stream
    let mut pcfg = PoolConfig::new(1, Duration::from_millis(50));
    pcfg.telemetry = Some(treg.clone());
    let pool = ServerPool::spawn_with(pcfg, registry, move |_w| {
        Ok(Box::new(
            ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())
                .with_forward_delay(Duration::from_millis(25)),
        ) as Box<dyn ServeBackend>)
    })
    .unwrap();

    // doomed: 30 steps at ~25ms each would take ~750ms; the 400ms
    // deadline expires after the first steps have landed but long
    // before the last (3 prompt tokens + 29 extensions just fits SEQ)
    let doomed = pool
        .submit_stream_with_deadline(
            "tenant0",
            vec![1, 2, 3],
            30,
            Some(Instant::now() + Duration::from_millis(400)),
        )
        .unwrap();
    let healthy = pool.submit_stream("tenant1", vec![4, 5], 5).unwrap();

    let (doomed_steps, healthy_logits) = std::thread::scope(|scope| {
        let d = scope.spawn(move || {
            let mut ok = 0usize;
            let mut shed = false;
            for r in doomed {
                match r {
                    Ok(reply) => {
                        assert!(!shed, "a step arrived after the terminal shed");
                        assert_eq!(reply.step, ok + 1);
                        ok += 1;
                    }
                    Err(ServeError::DeadlineExceeded { .. }) => shed = true,
                    Err(e) => panic!("doomed stream: unexpected error {e}"),
                }
            }
            assert!(shed, "the doomed stream was never shed");
            ok
        });
        let h = scope.spawn(move || {
            let mut out = Vec::new();
            for (j, r) in healthy.enumerate() {
                let r = r.unwrap_or_else(|e| panic!("healthy stream step {}: {e}", j + 1));
                if j == 0 {
                    assert_eq!(
                        r.batch_size, 2,
                        "the streams did not co-batch — the test lost its point"
                    );
                }
                out.push(r.logits);
            }
            out
        });
        (d.join().unwrap(), h.join().unwrap())
    });

    assert!(doomed_steps >= 1, "deadline expired before any step was produced");
    assert!(doomed_steps < 30, "the doomed stream was never shed");
    assert_eq!(healthy_logits.len(), 5, "the healthy stream lost steps");
    let mut prefix = vec![4, 5];
    for (j, logits) in healthy_logits.iter().enumerate() {
        let want = oracle.query("tenant1", prefix.clone()).unwrap().logits;
        for (i, (a, b)) in logits.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "healthy stream step {} logit {i} poisoned by the co-batched shed",
                j + 1
            );
        }
        prefix.push(greedy_next_token(logits));
    }

    let s = pool.stats();
    assert_eq!(s.shed_midstream, 1, "{s:?}");
    assert_eq!(s.shed_deadline, 1, "{s:?}");
    assert_eq!(s.stream_requests, 2, "{s:?}");
    assert_eq!(s.steps, doomed_steps + 5, "{s:?}");

    // telemetry mirrors the step-level counters at the same sites
    let snap = treg.snapshot();
    let tv = |key: &str| snap.iter().find(|e| e.key == key).map_or(0, |e| e.value);
    assert_eq!(tv("serve.steps"), s.steps as u64);
    assert_eq!(tv("serve.stream_requests"), s.stream_requests as u64);
    assert_eq!(tv("serve.shed_midstream"), s.shed_midstream as u64);
    assert_eq!(
        tv("pool.shed_deadline") + tv("serve.shed_deadline"),
        s.shed_deadline as u64,
        "shed_deadline views disagree"
    );
    pool.shutdown();
    oracle.shutdown();
}

/// A worker panic between decode steps surfaces as `WorkerDead` on the
/// live iterator; the steps delivered before the death are bit-correct.
#[test]
fn midstream_worker_death_surfaces_worker_dead() {
    let oracle = serial_oracle();
    let registry = synthetic_serve_registry(TENANTS, FIXTURE_SEED);
    let reg = registry.clone();
    let pool = ServerPool::spawn_with(
        PoolConfig::new(1, Duration::from_millis(1)),
        registry,
        move |_w| {
            // deterministic: the 4th backend call (= 4th decode step
            // of the only stream) panics the worker
            let cfg = FaultConfig { panic_after: Some(4), ..FaultConfig::default() };
            Ok(Box::new(FaultBackend::new(
                Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())),
                cfg,
            )) as Box<dyn ServeBackend>)
        },
    )
    .unwrap();

    let mut delivered: Vec<Vec<f32>> = Vec::new();
    let mut died = false;
    for (j, r) in pool.submit_stream("tenant2", vec![7, 8], 10).unwrap().enumerate() {
        match r {
            Ok(reply) => {
                assert!(!died, "a step arrived after the terminal death");
                assert_eq!(reply.step, j + 1);
                delivered.push(reply.logits);
            }
            Err(ServeError::WorkerDead { .. }) => died = true,
            Err(e) => panic!("unexpected terminal error: {e}"),
        }
    }
    assert!(died, "the worker death never surfaced on the stream");
    assert_eq!(delivered.len(), 3, "exactly the pre-panic steps must be delivered");

    let mut prefix = vec![7, 8];
    for (j, logits) in delivered.iter().enumerate() {
        let want = oracle.query("tenant2", prefix.clone()).unwrap().logits;
        for (i, (a, b)) in logits.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "pre-death step {} logit {i} diverged from the oracle",
                j + 1
            );
        }
        prefix.push(greedy_next_token(logits));
    }

    let s = pool.stats();
    assert_eq!(s.steps, 3, "{s:?}");
    assert!(s.workers[0].dead.is_some(), "the pool never noticed the death: {s:?}");
    pool.shutdown();
    oracle.shutdown();
}
