//! Integration: multi-adapter serving over one shared quantized base.
//!
//! These tests run fully offline: the batching/routing layer is
//! exercised through the deterministic `ReferenceBackend` (no PJRT,
//! no artifacts), while the shared base really does go through the
//! ICQ quantization pipeline (`quantize_model`) — the structure the
//! registry exists for: quantize/dequantize once, route many
//! adapters.

use std::sync::Arc;
use std::time::Duration;

use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
use irqlora::coordinator::{serve_registry, AdapterRegistry, BatchServer, ServerConfig};
use irqlora::coordinator::quantize_model;
use irqlora::model::checkpoint;
use irqlora::model::weights::NamedTensors;
use irqlora::quant::Method;
use irqlora::util::{Rng, Tensor};

const BATCH: usize = 8;
const SEQ: usize = 16;
const VOCAB: usize = 24;

fn tiny_base(seed: u64) -> NamedTensors {
    let mut rng = Rng::new(seed);
    let mut nt = NamedTensors::new();
    nt.push("embed", Tensor::new(&[VOCAB, 32], rng.normal_vec(VOCAB * 32, 0.0, 0.02)));
    nt.push("l0.attn_norm", Tensor::full(&[32], 1.0));
    nt.push("l0.wq", Tensor::new(&[32, 64], rng.normal_vec(32 * 64, 0.0, 0.02)));
    nt.push("l0.w2", Tensor::new(&[64, 32], rng.normal_vec(64 * 32, 0.0, 0.02)));
    nt.push("lm_head", Tensor::new(&[32, VOCAB], rng.normal_vec(32 * VOCAB, 0.0, 0.02)));
    nt
}

fn tiny_adapter(seed: u64) -> NamedTensors {
    let mut rng = Rng::new(seed);
    let (h, r, o) = (32usize, 4usize, 64usize);
    let mut nt = NamedTensors::new();
    nt.push("l0.wq.lora_a", Tensor::new(&[h, r], rng.normal_vec(h * r, 0.0, 0.5)));
    nt.push("l0.wq.lora_b", Tensor::new(&[r, o], rng.normal_vec(r * o, 0.0, 0.5)));
    nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
    nt
}

fn spawn_reference(
    registry: Arc<AdapterRegistry>,
    cfg: ServerConfig,
    delay: Duration,
) -> BatchServer {
    let reg = registry.clone();
    BatchServer::spawn_with(cfg, registry, move || {
        let mut b = ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base());
        b.forward_delay = delay;
        Ok(Box::new(b) as Box<dyn ServeBackend>)
    })
    .unwrap()
}

/// ≥3 adapters through one `BatchServer` over one shared
/// ICQ-quantized base; batches mixing adapters never
/// cross-contaminate: every reply is bit-identical to the same
/// (adapter, prompt) served alone.
#[test]
fn three_plus_adapters_one_quantized_base_no_cross_contamination() {
    let base = tiny_base(11);
    let qm = quantize_model(&base, Method::NfIcq { k: 4 }, 7).unwrap();
    let registry = Arc::new(serve_registry(&qm, (1.0, 1.0)));
    for (i, seed) in [21u64, 22, 23, 24].iter().enumerate() {
        registry.register(&format!("tenant{i}"), tiny_adapter(*seed)).unwrap();
    }
    assert_eq!(registry.len(), 4);

    let prompts: Vec<Vec<i32>> = (0..16)
        .map(|i| {
            (0..(1 + i % SEQ))
                .map(|t| ((i * 7 + t * 3) % (VOCAB - 1)) as i32 + 1)
                .collect()
        })
        .collect();
    let adapter_of = |i: usize| format!("tenant{}", i % 4);

    // oracle: each (adapter, prompt) served alone, sequentially, on
    // the per-group serial path
    let mut expect = Vec::new();
    {
        let solo = spawn_reference(
            registry.clone(),
            ServerConfig::new(Duration::from_millis(1)).serial(),
            Duration::ZERO,
        );
        for (i, p) in prompts.iter().enumerate() {
            expect.push(solo.query(&adapter_of(i), p.clone()).unwrap().logits);
        }
        solo.shutdown();
    }

    // mixed load: submit everything up front, so the batcher's window
    // deterministically drains full, multi-adapter pending sets that
    // each run as ONE fused forward
    let server = spawn_reference(
        registry.clone(),
        ServerConfig::new(Duration::from_millis(200)),
        Duration::ZERO,
    );
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| server.submit(&adapter_of(i), p.clone()).unwrap())
        .collect();
    let replies: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap())
        .collect();

    for (i, r) in replies.iter().enumerate() {
        assert_eq!(r.adapter, adapter_of(i));
        assert_eq!(
            r.logits, expect[i],
            "request {i} (adapter {}) got contaminated logits",
            r.adapter
        );
    }

    let stats = server.stats();
    assert_eq!(stats.requests, prompts.len());
    assert_eq!(stats.batch_occupancy_sum, prompts.len());
    // fused drains: ONE forward per drained batch even though every
    // batch mixed all four adapters
    assert!(stats.batches < prompts.len(), "no batching: {stats:?}");
    assert_eq!(stats.fused_batches, stats.batches, "{stats:?}");
    assert!(
        stats.fused_adapters > stats.fused_batches,
        "drains never mixed adapters: {stats:?}"
    );
    assert_eq!(stats.per_adapter.len(), 4);
    for i in 0..4 {
        let a = &stats.per_adapter[&adapter_of(i)];
        assert_eq!(a.requests, 4, "tenant{i}: {a:?}");
    }
    server.shutdown();
}

/// Capacity-1 cache: every lookup alternation evicts; re-merged and
/// disk-reloaded adapters must come back bit-identical.
#[test]
fn adapter_cache_eviction_reload_bit_identical() {
    let base = tiny_base(31);
    let qm = quantize_model(&base, Method::NfIcq { k: 4 }, 3).unwrap();
    let registry = AdapterRegistry::with_capacity(qm.dequantized.clone(), (1.0, 1.0), 1);
    registry.register("a", tiny_adapter(41)).unwrap();

    let path = std::env::temp_dir().join(format!("adapter_b_{}.irqc", std::process::id()));
    checkpoint::save(&tiny_adapter(42), &path).unwrap();
    registry.register_file("b", &path).unwrap();

    let a1 = registry.merged("a").unwrap();
    let b1 = registry.merged("b").unwrap(); // evicts a
    let a2 = registry.merged("a").unwrap(); // re-merges a, evicts b
    let b2 = registry.merged("b").unwrap(); // reloads b from disk, evicts a

    for (nt1, nt2, who) in [(&a1, &a2, "a"), (&b1, &b2, "b")] {
        assert_eq!(nt1.names(), nt2.names());
        for (name, t) in nt1.iter() {
            assert_eq!(
                t.data(),
                nt2.get(name).unwrap().data(),
                "{who}/{name} not bit-identical after evict/reload"
            );
        }
    }
    // merging folded the betas away in both flavors
    assert!(a1.get("betas").unwrap().data().iter().all(|&x| x == 0.0));
    assert!(b1.get("betas").unwrap().data().iter().all(|&x| x == 0.0));

    let s = registry.stats();
    assert_eq!((s.hits, s.misses, s.evictions), (0, 4, 3), "{s:?}");
    std::fs::remove_file(path).ok();
}

/// A failing backend factory must surface as a clean spawn error —
/// not a hang, not a poisoned worker.
#[test]
fn worker_init_failure_surfaces_cleanly() {
    let registry = Arc::new(AdapterRegistry::new(tiny_base(51), (0.0, 0.0)));
    let err = BatchServer::spawn_with(
        ServerConfig::new(Duration::from_millis(1)),
        registry,
        || anyhow::bail!("no device for you"),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("server init failed") && msg.contains("no device for you"),
        "{msg}"
    );
}

/// The PJRT spawn path in the offline stub build is a real worker-init
/// failure (no PJRT): it must error cleanly too, never leak a wedged
/// worker. (With artifacts + real PJRT this path is covered by
/// integration_serve.rs instead.)
#[test]
fn pjrt_spawn_without_runtime_errors_cleanly() {
    use irqlora::runtime::Manifest;
    let Ok(manifest) = Manifest::load("artifacts") else {
        // no artifacts: exercise the error path via a doomed factory
        let registry = Arc::new(AdapterRegistry::new(tiny_base(52), (0.0, 0.0)));
        let r = BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(1)),
            registry.clone(),
            {
                let reg = registry.clone();
                move || {
                    // mimic BatchServer::spawn with a runtime that cannot exist
                    let rt = irqlora::runtime::Runtime::cpu()?;
                    let _ = (rt.platform(), reg.base());
                    anyhow::bail!("runtime available but no artifacts to serve")
                }
            },
        );
        assert!(r.is_err());
        return;
    };
    // artifacts exist but the stub runtime can't execute: still clean
    let registry = Arc::new(AdapterRegistry::new(tiny_base(53), (0.0, 0.0)));
    let r = BatchServer::spawn(
        manifest,
        "xs",
        ServerConfig::new(Duration::from_millis(1)),
        registry,
    );
    // either a working PJRT (ok) or a clean error — never a hang
    if let Err(e) = r {
        assert!(!format!("{e:#}").is_empty());
    }
}

/// Shutdown with requests still queued behind a slow forward: every
/// submitted receiver must still get its reply (drain semantics).
#[test]
fn shutdown_drains_in_flight_requests() {
    let base = tiny_base(61);
    let qm = quantize_model(&base, Method::Nf { k: 4 }, 1).unwrap();
    let registry = Arc::new(serve_registry(&qm, (0.0, 0.0)));
    registry.register("a", tiny_adapter(62)).unwrap();
    let server = spawn_reference(
        registry,
        ServerConfig::new(Duration::from_millis(1)),
        Duration::from_millis(15),
    );
    let rxs: Vec<_> = (0..6)
        .map(|i| server.submit("a", vec![1 + i as i32, 2, 3]).unwrap())
        .collect();
    server.shutdown(); // joins the worker; queued requests drain first
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i}: reply channel closed without a reply"))
            .unwrap();
        assert_eq!(r.adapter, "a");
        assert_eq!(r.logits.len(), VOCAB);
    }
}

/// Malformed prompts and unknown adapters are rejected at submit time
/// and never occupy a batch slot.
#[test]
fn submit_rejects_malformed_and_unknown_before_batching() {
    let registry = Arc::new(AdapterRegistry::new(tiny_base(71), (0.0, 0.0)));
    registry.register("good", tiny_adapter(72)).unwrap();
    let server = spawn_reference(
        registry,
        ServerConfig::new(Duration::from_millis(1)),
        Duration::ZERO,
    );

    let err = server.submit("good", vec![]).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    let err = server.submit("good", vec![1; SEQ + 1]).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    let err = server.submit("nope", vec![1, 2]).unwrap_err();
    assert!(format!("{err:#}").contains("unknown adapter"), "{err:#}");

    // server healthy afterwards, and the rejects never reached a batch
    let ok = server.query("good", vec![1, 2, 3]).unwrap();
    assert_eq!(ok.logits.len(), VOCAB);
    let s = server.stats();
    assert_eq!(s.rejected, 3);
    assert_eq!(s.requests, 1);
    assert_eq!(s.batches, 1);
    server.shutdown();
}

/// Adapters registered while the server is live become routable
/// immediately; removed adapters get rejected at submit.
#[test]
fn live_registration_and_removal() {
    let registry = Arc::new(AdapterRegistry::new(tiny_base(81), (1.0, 1.0)));
    registry.register("a", tiny_adapter(82)).unwrap();
    let server = spawn_reference(
        registry.clone(),
        ServerConfig::new(Duration::from_millis(1)),
        Duration::ZERO,
    );

    assert!(server.submit("late", vec![1, 2]).is_err());
    registry.register("late", tiny_adapter(83)).unwrap();
    let r = server.query("late", vec![1, 2]).unwrap();
    assert_eq!(r.adapter, "late");

    registry.remove("late");
    assert!(server.submit("late", vec![1, 2]).is_err());
    // the original tenant is untouched
    assert!(server.query("a", vec![3, 4]).is_ok());
    server.shutdown();
}

/// A backend that ERRORS (not panics) on one adapter inside a fused
/// mixed-adapter drain must not poison co-batched tenants: the worker
/// falls back to per-group execution, the healthy group's replies stay
/// bit-identical to the serial oracle, and only the failing adapter's
/// requests error. (A panicking backend is the pool-level blast-radius
/// test in failure_injection.rs — this covers the recoverable case.)
#[test]
fn fused_batch_isolates_an_erroring_adapter_via_per_group_fallback() {
    struct ErrOnAdapter(ReferenceBackend);
    impl ServeBackend for ErrOnAdapter {
        fn shape(&self) -> (usize, usize, usize) {
            self.0.shape()
        }
        fn forward(
            &mut self,
            name: &str,
            generation: u64,
            weights: &std::sync::Arc<NamedTensors>,
            tokens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            if name == "flaky" {
                anyhow::bail!("injected transient failure for '{name}'");
            }
            self.0.forward(name, generation, weights, tokens)
        }
        // no forward_fused override: the default per-group scatter
        // aborts on the flaky group's error, which is exactly what
        // triggers the server's per-group fallback
    }

    let base = tiny_base(91);
    let registry = Arc::new(AdapterRegistry::new(base, (1.0, 1.0)));
    registry.register("good", tiny_adapter(92)).unwrap();
    registry.register("flaky", tiny_adapter(93)).unwrap();

    // serial oracle for the healthy tenant
    let good_prompt = vec![2, 5, 1];
    let expected = {
        let reg = registry.clone();
        let solo = BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(1)).serial(),
            registry.clone(),
            move || {
                Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        let logits = solo.query("good", good_prompt.clone()).unwrap().logits;
        solo.shutdown();
        logits
    };

    let reg = registry.clone();
    let server = BatchServer::spawn_with(
        // 200ms window: both submissions land in ONE fused drain
        ServerConfig::new(Duration::from_millis(200)),
        registry,
        move || {
            Ok(Box::new(ErrOnAdapter(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())))
                as Box<dyn ServeBackend>)
        },
    )
    .unwrap();

    let good_rx = server.submit("good", good_prompt.clone()).unwrap();
    let flaky_rx = server.submit("flaky", vec![1, 2]).unwrap();

    let good_reply = good_rx.recv().unwrap().expect("healthy co-batched tenant failed");
    assert_eq!(
        good_reply.logits, expected,
        "fallback-served healthy tenant diverged from the serial oracle"
    );
    let flaky_err = flaky_rx.recv().unwrap().unwrap_err();
    assert!(flaky_err.to_string().contains("injected transient failure"), "{flaky_err}");

    // the worker survived the error — it keeps serving
    let again = server.query("good", good_prompt).unwrap();
    assert_eq!(again.logits, expected);
    server.shutdown();
}
