//! Concurrency battery for the sharded serving pool and the registry
//! it routes through — the PR-2/PR-3 claims (LRU eviction
//! bit-identity, no cross-tenant contamination, generation tagging)
//! exercised under *actual* multi-threaded contention.
//!
//! Everything runs offline on the deterministic `ReferenceBackend`:
//! the shared base really goes through ICQ quantization, the merged
//! cache is forced *below* the adapter count so eviction/re-merge
//! races stay hot, and every pooled reply is compared bit-for-bit
//! against a serially-computed single-`BatchServer` oracle.
//!
//! `scripts/verify.sh` runs this file a second time with
//! `IRQLORA_SERVE_WORKERS=4` exported so the env-sized pool path is
//! covered explicitly (the tests themselves also floor the worker
//! count at 4), and a third time with `IRQLORA_SERVE_BACKEND=native`
//! so the whole battery replays over the native CPU backend: the
//! pooled side is built through the HAL registry's validated factory,
//! while the serial oracle stays pinned to `ReferenceBackend` — so a
//! native-vs-reference bit divergence fails these assertions, not
//! just the dedicated backend-matrix tests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
use irqlora::coordinator::pool::{home_worker, serve_workers, PoolConfig, ServerPool};
use irqlora::coordinator::{quantize_model, AdapterRegistry, BatchServer, ServerConfig};
use irqlora::hal::{BackendRegistry, BackendRequest, NativeBackend};
use irqlora::lora::merge::merge_adapter;
use irqlora::model::weights::NamedTensors;
use irqlora::quant::Method;
use irqlora::util::{Rng, Tensor};

const BATCH: usize = 4;
const SEQ: usize = 16;
const VOCAB: usize = 24;
const N_ADAPTERS: usize = 8;
/// Merged-weight cache capacity — deliberately below [`N_ADAPTERS`]
/// (the `IRQLORA_ADAPTER_CACHE=2` regime) so concurrent lookups keep
/// evicting and re-merging each other's entries.
const CACHE_CAP: usize = 2;

fn tiny_base(seed: u64) -> NamedTensors {
    let mut rng = Rng::new(seed);
    let mut nt = NamedTensors::new();
    nt.push("embed", Tensor::new(&[VOCAB, 32], rng.normal_vec(VOCAB * 32, 0.0, 0.02)));
    nt.push("l0.wq", Tensor::new(&[32, 64], rng.normal_vec(32 * 64, 0.0, 0.02)));
    nt.push("lm_head", Tensor::new(&[32, VOCAB], rng.normal_vec(32 * VOCAB, 0.0, 0.02)));
    nt
}

fn tiny_adapter(seed: u64) -> NamedTensors {
    let mut rng = Rng::new(seed);
    let (h, r, o) = (32usize, 4usize, 64usize);
    let mut nt = NamedTensors::new();
    nt.push("l0.wq.lora_a", Tensor::new(&[h, r], rng.normal_vec(h * r, 0.0, 0.5)));
    nt.push("l0.wq.lora_b", Tensor::new(&[r, o], rng.normal_vec(r * o, 0.0, 0.5)));
    nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
    nt
}

/// Registry over an actually-ICQ-quantized base, with the merged
/// cache forced below the adapter count.
fn contended_registry(seed: u64) -> Arc<AdapterRegistry> {
    let base = tiny_base(seed);
    let qm = quantize_model(&base, Method::NfIcq { k: 4 }, seed ^ 1).unwrap();
    let registry = Arc::new(AdapterRegistry::with_capacity(
        qm.dequantized.clone(),
        (1.0, 1.0),
        CACHE_CAP,
    ));
    for i in 0..N_ADAPTERS {
        registry
            .register(&format!("tenant{i}"), tiny_adapter(100 + seed + i as u64))
            .unwrap();
    }
    registry
}

/// Pool over the env-selected HAL backend (`IRQLORA_SERVE_BACKEND`,
/// default `reference`). The request is validated against the
/// backend's capability manifest up front — the same typed-error path
/// `irqlora serve --backend` takes — so a misconfigured rerun fails at
/// construction, not mid-battery. When a forward delay is needed the
/// backend is built by name (the delay knob is a concrete-type
/// builder); the delay-free case goes through the registry factory
/// verbatim. The serial oracles below stay pinned to
/// `ReferenceBackend` either way.
fn env_backend_pool(
    workers: usize,
    registry: Arc<AdapterRegistry>,
    delay: Duration,
) -> ServerPool {
    let name = irqlora::util::env::serve_backend();
    let mut req = BackendRequest::new(BATCH, SEQ, VOCAB);
    req.workers = workers;
    let hal = BackendRegistry::builtin();
    hal.resolve(&name, &req)
        .unwrap_or_else(|e| panic!("backend '{name}' rejected for this battery: {e}"));
    let pcfg = PoolConfig::new(workers, Duration::from_millis(2));
    if delay.is_zero() {
        let factory = hal
            .pool_factory(&name, &req, registry.base().clone(), "test")
            .unwrap_or_else(|e| panic!("backend '{name}': {e}"));
        return ServerPool::spawn_with(pcfg, registry, factory).unwrap();
    }
    let base = registry.base().clone();
    ServerPool::spawn_with(pcfg, registry, move |_w| {
        let b: Box<dyn ServeBackend> = match name.as_str() {
            "native" => Box::new(
                NativeBackend::new(BATCH, SEQ, VOCAB, &base).with_forward_delay(delay),
            ),
            _ => Box::new(
                ReferenceBackend::new(BATCH, SEQ, VOCAB, &base).with_forward_delay(delay),
            ),
        };
        Ok(b)
    })
    .unwrap()
}

/// The unique (adapter, prompt) stream every test thread replays.
fn request_stream() -> Vec<(String, Vec<i32>)> {
    (0..48)
        .map(|i| {
            let adapter = format!("tenant{}", i % N_ADAPTERS);
            let len = 1 + (i * 5) % SEQ;
            let prompt: Vec<i32> = (0..len)
                .map(|t| ((i * 13 + t * 7) % (VOCAB - 1)) as i32 + 1)
                .collect();
            (adapter, prompt)
        })
        .collect()
}

/// ≥4 workers, 8 adapters, cache capacity 2: a storm of submitters
/// replaying one request stream from different offsets. Every pooled
/// reply must be bit-identical to the same (adapter, prompt) served
/// serially by a single `BatchServer` — across worker shards, LRU
/// evictions, re-merges, and mixed batches, no reply may ever see
/// another adapter's weights or another batch's composition.
#[test]
fn pool_replies_bit_identical_to_serial_oracle_under_contention() {
    let registry = contended_registry(11);
    let stream = request_stream();

    // oracle: one worker, every request served alone, in order, on
    // the pre-fusion per-group SERIAL path — the fused pool replies
    // must match it bit for bit
    let mut expected: Vec<Vec<f32>> = Vec::with_capacity(stream.len());
    {
        let reg = registry.clone();
        let solo = BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(1)).serial(),
            registry.clone(),
            move || {
                Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        for (adapter, prompt) in &stream {
            expected.push(solo.query(adapter, prompt.clone()).unwrap().logits);
        }
        solo.shutdown();
    }
    // the oracle alone must already have churned the tiny cache
    let oracle_evictions = registry.stats().evictions;
    assert!(
        oracle_evictions > 0,
        "cache capacity {CACHE_CAP} did not force evictions: {:?}",
        registry.stats()
    );

    let n_workers = serve_workers().max(4);
    let pool = env_backend_pool(n_workers, registry.clone(), Duration::ZERO);
    assert!(pool.workers() >= 4);

    const SUBMITTERS: usize = 6;
    let mismatches = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..SUBMITTERS {
            let pool = &pool;
            let stream = &stream;
            let expected = &expected;
            let mismatches = &mismatches;
            scope.spawn(move || {
                // each thread walks the stream from its own offset and
                // keeps a window of async handles in flight
                let mut inflight: Vec<(usize, irqlora::coordinator::Pending)> = Vec::new();
                for k in 0..stream.len() {
                    let i = (k + t * 7) % stream.len();
                    let (adapter, prompt) = &stream[i];
                    inflight.push((i, pool.submit_async(adapter, prompt.clone()).unwrap()));
                    if inflight.len() >= 8 {
                        for (j, h) in inflight.drain(..) {
                            let r = h.wait().unwrap();
                            if r.logits != expected[j] {
                                mismatches.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                for (j, h) in inflight.drain(..) {
                    let r = h.wait().unwrap();
                    if r.logits != expected[j] {
                        mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert_eq!(
        mismatches.load(Ordering::Relaxed),
        0,
        "pooled replies diverged from the serial oracle"
    );

    let s = pool.stats();
    let total = SUBMITTERS * stream.len();
    assert_eq!(s.requests, total, "{s:?}");
    assert_eq!(s.alive(), n_workers);
    assert_eq!(s.queue_depth(), 0);
    assert_eq!(s.rejected, 0);
    assert_eq!(s.per_adapter.len(), N_ADAPTERS);
    for i in 0..N_ADAPTERS {
        assert_eq!(
            s.per_adapter[&format!("tenant{i}")].requests,
            total / N_ADAPTERS,
            "{s:?}"
        );
    }
    assert_eq!(s.workers.iter().map(|w| w.routed).sum::<usize>(), total);
    // every pooled forward was a fused drain (the serial path served
    // only the oracle), and the fingerprint/device cache plumbing
    // recorded its misses
    assert_eq!(s.fused_batches, s.batches, "{s:?}");
    assert!(s.fused_batches >= 1, "{s:?}");
    assert!(s.upload_misses >= 1, "{s:?}");
    // contention kept re-merging past the oracle's churn
    assert!(
        registry.stats().evictions > oracle_evictions,
        "pooled run added no evictions: {:?}",
        registry.stats()
    );
    pool.shutdown();
}

/// Work stealing under a skewed load: one hot adapter floods its home
/// worker past the park threshold while the other workers sit idle —
/// the idle workers must pull parked requests from the hot worker's
/// overflow (steals > 0), and every reply must STILL be bit-identical
/// to the per-group serial single-server oracle. Skipped when the
/// environment pins the legacy scheduler (`IRQLORA_SERVE_STEAL=0`);
/// the rest of this battery covers that path.
#[test]
fn stealing_balances_a_saturated_worker_bit_identically() {
    if !irqlora::coordinator::serve_steal() {
        return;
    }
    let registry = contended_registry(59);
    const HOT: &str = "tenant0";
    const N_REQ: usize = 64;
    let prompts: Vec<Vec<i32>> = (0..N_REQ)
        .map(|i| {
            let len = 1 + (i * 3) % SEQ;
            (0..len).map(|t| ((i * 11 + t * 5) % (VOCAB - 1)) as i32 + 1).collect()
        })
        .collect();

    // serial single-server oracle
    let mut expected: Vec<Vec<f32>> = Vec::with_capacity(N_REQ);
    {
        let reg = registry.clone();
        let solo = BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(1)).serial(),
            registry.clone(),
            move || {
                Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        for p in &prompts {
            expected.push(solo.query(HOT, p.clone()).unwrap().logits);
        }
        solo.shutdown();
    }

    // slow backend: the home worker cannot keep up with an open-loop
    // burst, so in-flight crosses the park threshold (2 × BATCH = 8)
    // and idle workers get something to steal
    let pool = env_backend_pool(4, registry, Duration::from_millis(5));
    assert!(pool.stealing());
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| pool.submit_async(HOT, p.clone()).unwrap())
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().unwrap_or_else(|e| panic!("request {i}: {e:#}"));
        assert_eq!(r.logits, expected[i], "stolen/parked request {i} diverged");
    }

    let s = pool.stats();
    assert_eq!(s.requests, N_REQ, "{s:?}");
    assert_eq!(s.parked, 0, "overflow not drained: {s:?}");
    assert!(
        s.steals > 0,
        "64 open-loop requests against a 5ms-per-forward home worker \
         never got stolen by the 3 idle workers: {s:?}"
    );
    assert_eq!(s.spills, 0, "stealing scheduler must not push-spill: {s:?}");
    pool.shutdown();
}

/// Shutdown drains: handles submitted (not yet replied) before
/// `shutdown` all resolve with correct logits — none may hang or get
/// dropped, even with a slow backend and requests queued on several
/// workers.
#[test]
fn shutdown_drains_all_inflight_async_handles() {
    let registry = contended_registry(23);
    let stream = request_stream();

    // oracle for the wave we will strand in flight (serial per-group
    // path, so fused drains are checked against the pre-fusion truth)
    let mut expected: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
    {
        let reg = registry.clone();
        let solo = BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(1)).serial(),
            registry.clone(),
            move || {
                Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        for i in 0..16 {
            let (adapter, prompt) = &stream[i];
            expected.insert(i, solo.query(adapter, prompt.clone()).unwrap().logits);
        }
        solo.shutdown();
    }

    let pool = env_backend_pool(
        serve_workers().max(4),
        registry,
        Duration::from_millis(5), // keep the queues non-empty at shutdown
    );
    let handles: Vec<(usize, _)> = (0..16)
        .map(|i| {
            let (adapter, prompt) = &stream[i];
            (i, pool.submit_async(adapter, prompt.clone()).unwrap())
        })
        .collect();
    pool.shutdown(); // joins every worker; queued requests drain first
    for (i, h) in handles {
        let r = h
            .wait()
            .unwrap_or_else(|e| panic!("handle {i} lost in shutdown: {e:#}"));
        assert_eq!(r.logits, expected[&i], "handle {i} got wrong logits");
    }
}

/// Satellite regression (registry race): `merged_tagged` must never
/// hand back a (generation, weights) pair that doesn't match — under
/// a register/evict storm, every returned tensor must be bit-identical
/// to the merge of exactly the source registered at the returned
/// generation, and a completed re-register must not be bypassed by a
/// lookup that finishes after it (the pre-fix code could return the
/// previous generation's weights without retrying).
#[test]
fn registry_no_stale_generation_under_register_evict_storm() {
    const SEEDS: u64 = 5;
    const MASKS: (f32, f32) = (1.0, 1.0);
    let registry = Arc::new(AdapterRegistry::with_capacity(tiny_base(31), MASKS, CACHE_CAP));

    // expected merged weights per seed, computed serially up front
    let expected: Vec<NamedTensors> = (0..SEEDS)
        .map(|s| merge_adapter(&tiny_adapter(500 + s), MASKS).unwrap())
        .collect();

    registry.register("x", tiny_adapter(500)).unwrap();
    let log: Arc<Mutex<BTreeMap<u64, u64>>> = Arc::new(Mutex::new(BTreeMap::new()));
    log.lock()
        .unwrap()
        .insert(registry.generation("x").unwrap(), 0);

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        // mutator: re-register (bumping the generation) and evict in a
        // tight loop; the single mutator means generation("x") right
        // after register is exactly the generation it created
        {
            let registry = registry.clone();
            let log = log.clone();
            let stop = stop.clone();
            scope.spawn(move || {
                for i in 1..=300u64 {
                    let seed = i % SEEDS;
                    registry.register("x", tiny_adapter(500 + seed)).unwrap();
                    log.lock()
                        .unwrap()
                        .insert(registry.generation("x").unwrap(), seed);
                    if i % 3 == 0 {
                        registry.evict("x");
                    }
                }
                stop.store(true, Ordering::Release);
            });
        }

        for _ in 0..4 {
            let registry = registry.clone();
            let log = log.clone();
            let stop = stop.clone();
            let expected = &expected;
            scope.spawn(move || {
                let mut lookups = 0usize;
                while !stop.load(Ordering::Acquire) || lookups == 0 {
                    let (g, m) = registry.merged_tagged("x").unwrap();
                    lookups += 1;
                    // the mutator logs each generation right after
                    // registering it; spin briefly for the log entry
                    let t0 = Instant::now();
                    let seed = loop {
                        if let Some(s) = log.lock().unwrap().get(&g) {
                            break *s;
                        }
                        assert!(
                            t0.elapsed() < Duration::from_secs(5),
                            "generation {g} was returned but never registered"
                        );
                        std::thread::yield_now();
                    };
                    let want = &expected[seed as usize];
                    assert_eq!(m.names(), want.names(), "generation {g}");
                    for (name, t) in want.iter() {
                        assert_eq!(
                            m.get(name).unwrap().data(),
                            t.data(),
                            "generation {g} ('{name}') returned weights that are not \
                             the merge of the source registered at that generation"
                        );
                    }
                }
            });
        }
    });

    // steady state: the surviving entry is the final registration
    let final_gen = registry.generation("x").unwrap();
    let (g, m) = registry.merged_tagged("x").unwrap();
    assert_eq!(g, final_gen, "post-storm lookup returned a stale generation");
    let want = &expected[(300 % SEEDS) as usize];
    for (name, t) in want.iter() {
        assert_eq!(m.get(name).unwrap().data(), t.data(), "{name}");
    }
}

/// The worker-count env knob must actually be honored: when
/// `scripts/verify.sh` reruns this file with `IRQLORA_SERVE_WORKERS=4`
/// exported, `serve_workers()` (and thus every `workers: 0` pool) must
/// return exactly that value — without this assertion the rerun could
/// not tell a broken knob from the `.max(4)` floor the other tests
/// apply. Read-only env access; nothing here mutates process state.
#[test]
fn serve_workers_honors_env_when_set() {
    if let Ok(v) = std::env::var("IRQLORA_SERVE_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if (1..=64).contains(&n) {
                assert_eq!(
                    serve_workers(),
                    n,
                    "IRQLORA_SERVE_WORKERS={v} was not honored"
                );
            }
        }
    }
}

/// Adapter-affinity sanity at the pool level: with no contention, an
/// adapter's traffic lands entirely on `home_worker(adapter, N)`, so
/// its merged-weight lookups always come from the same worker thread.
#[test]
fn affinity_routes_every_adapter_to_its_home_worker() {
    let registry = contended_registry(47);
    let n_workers = serve_workers().max(4);
    let pool = env_backend_pool(n_workers, registry, Duration::ZERO);
    for i in 0..N_ADAPTERS {
        let name = format!("tenant{i}");
        for round in 0..3 {
            let h = pool.submit_async(&name, vec![1 + round as i32, 2]).unwrap();
            assert_eq!(
                h.worker(),
                home_worker(&name, n_workers),
                "{name} strayed off its home worker"
            );
            h.wait().unwrap();
        }
    }
    let s = pool.stats();
    assert_eq!(s.spills, 0, "uncontended traffic must not spill: {s:?}");
    assert_eq!(s.reroutes, 0);
    pool.shutdown();
}
